//! The sweep planner: searches hierarchy *shapes* instead of running one.
//!
//! The paper's central claim is that the hierarchy shape — level count,
//! fan-outs, and the per-level averaging intervals (K1, K2, S) — trades
//! communication for convergence.  This module is the decision procedure
//! that connects the three subsystems the claim spans:
//!
//! - **topology / comm::cost** — [`enumerate`] walks candidate
//!   [`HierTopology`] shapes (divisor chains over P, per-level
//!   [`LinkClass`] assignments) and [`score`] composes
//!   `CostModel::allreduce_seconds`/`allreduce_bytes` over levels with the
//!   exact per-level event counts of [`HierSchedule::reduction_counts`],
//!   reproducing the engine's accounting conventions (concurrent groups at
//!   one level are charged the max, i.e. one group's time; size-1 levels
//!   below the top are free no-ops);
//! - **theory** — each candidate's `(K1, K2, S)` projection is scored with
//!   [`theory::thm34_budget_bound`], schedules include the
//!   [`theory::optimal_k2`] point, and the K2 search is capped at
//!   [`theory::max_k2_condition_35`] so the bound stays a guarantee
//!   (property-tested invariants in rust/tests/proptests.rs);
//! - **coordinator/engine** — [`validate`] replays the top candidates as
//!   short deterministic training runs and reports modelled-vs-measured
//!   communication deltas (near-zero by construction: the closed form and
//!   the engine share the cost model — a drift here is a regression).
//!
//! Ranking: `time_to_target = makespan_s · bound / bound_floor` — the
//! candidate's straggler-aware modelled wall clock for the step horizon
//! (equal to `compute_s + comm_s` under homogeneous compute; the event
//! timeline's makespan when the sweep is given `--het`/`--straggler`),
//! inflated by how much looser the candidate's fixed-budget convergence
//! bound is than the best bound in the search space.  Deterministic: the
//! only randomness is the seeded straggler stream, fixed per sweep;
//! stable tie-breaks.
//!
//! The `sweep` CLI subcommand (main.rs) drives this end to end and emits a
//! machine-readable `SWEEP_<p>.json` report (see [`report`]); the
//! golden-trace suite (rust/tests/golden_trace.rs) pins the validation
//! runs bit-for-bit across collectives.

pub mod report;

use anyhow::{bail, Result};

use crate::algorithms::{policy::K2_CLAMP_CAP, HierSchedule, PolicyKind};
use crate::comm::{CollectiveKind, Compression, CostModel, ReduceStrategy};
use crate::config::{BackendKind, RunConfig};
use crate::coordinator::{self, Trainer};
use crate::data::ClassifyData;
use crate::driver;
use crate::metrics::RunRecord;
use crate::native::NativeMlp;
use crate::optimizer::LrSchedule;
use crate::sim::{self, FaultPlan, FaultSpec, HetSpec};
use crate::theory::{self, BoundParams};
use crate::topology::{HierTopology, LinkClass};
use crate::util::rng::Pcg32;

/// Search-space description for one sweep over a fixed learner count P.
#[derive(Debug, Clone)]
pub struct SweepSpace {
    pub p: usize,
    /// Smallest / largest hierarchy depth enumerated (inclusive).
    pub min_levels: usize,
    pub max_levels: usize,
    /// Innermost-interval grid; inner chains grow geometrically (ratio 2)
    /// from each entry.
    pub k1_grid: Vec<u64>,
    /// Upper cap on the outermost interval before the condition-(3.5)
    /// clamp is applied.
    pub k2_max: u64,
    /// Also enumerate, for every shape with ≥ 3 levels, a variant whose
    /// outermost level is charged to the cross-rack fabric tier.
    pub use_rack: bool,
    /// When false the space collapses to the K-AVG family: the single
    /// shape `[1, P]` (every learner its own cluster) under flat
    /// single-interval schedules — the paper's baseline, and the shape the
    /// planner must degenerate to when local averaging is disabled.
    pub local_averaging: bool,
    /// Non-static schedule policy to enumerate *next to* the static
    /// entries (`sweep --schedule`): every shape additionally gets a
    /// policy variant, scored by replaying the policy through the
    /// virtual-time event engine instead of the closed form.  `Static`
    /// (the default) adds nothing — the space and its ranking stay
    /// bit-stable with the pre-policy planner.
    pub policy: PolicyKind,
    /// Compressed-payload variants to enumerate *next to* every dense
    /// candidate (`sweep --compress`): each spec gets a twin per (shape ×
    /// schedule × policy) entry, priced by the compressed wire bytes
    /// ([`Compression::payload_bytes`]) exactly as the engine's reducer
    /// prices a compressed run.  Empty (the default) adds nothing — the
    /// space and its ranking stay bit-stable with the dense planner.
    pub compress: Vec<Compression>,
}

impl SweepSpace {
    pub fn new(p: usize) -> Result<SweepSpace> {
        if p < 2 {
            bail!("sweep needs p >= 2 learners (got {p})");
        }
        Ok(SweepSpace {
            p,
            min_levels: 2,
            max_levels: 4,
            k1_grid: vec![1, 2, 4],
            k2_max: 256,
            use_rack: true,
            local_averaging: true,
            policy: PolicyKind::Static,
            compress: Vec::new(),
        })
    }

    /// Reject contradictory knob combinations instead of silently
    /// reinterpreting them ([`rank`] calls this before enumerating).
    pub fn validate(&self) -> Result<()> {
        if self.p < 2 {
            bail!("sweep needs p >= 2 learners (got {})", self.p);
        }
        if self.p > crate::topology::MAX_P {
            bail!(
                "sweep --p {} exceeds the supported maximum of {} learners (2^24); \
                 timeline-only sweeps handle up to --p 1048576",
                self.p,
                crate::topology::MAX_P
            );
        }
        if self.min_levels < 2 {
            bail!("levels-min must be >= 2 (got {})", self.min_levels);
        }
        if self.min_levels > self.max_levels {
            bail!(
                "levels-min {} exceeds levels-max {}",
                self.min_levels,
                self.max_levels
            );
        }
        if self.k1_grid.is_empty() || self.k1_grid.iter().any(|&k| k == 0) {
            bail!("k1-grid must be non-empty with entries >= 1 (got {:?})", self.k1_grid);
        }
        if self.k2_max == 0 {
            bail!("k2-max must be >= 1");
        }
        self.policy.validate()?;
        if self.compress.iter().any(|c| c.is_none()) {
            bail!(
                "sweep --compress enumerates compressed variants *next to* the dense \
                 entries; listing \"none\" would duplicate every dense candidate"
            );
        }
        Ok(())
    }

    /// The condition-(3.5) clamp on this space's K2 search: theorems
    /// 3.2/3.3 only hold below it, so neither `optimal_k2` nor the ranked
    /// schedules look past it.
    pub fn k2_cap(&self, bound: &BoundParams) -> u64 {
        theory::max_k2_condition_35(bound, self.k2_max).unwrap_or(1)
    }
}

/// What a sweep scores against: the cost model, the convergence-bound
/// regime, and the modelled workload (message size, horizon, step clock).
#[derive(Debug, Clone, Copy)]
pub struct ScoreCtx {
    pub cost: CostModel,
    pub strategy: ReduceStrategy,
    /// Bound regime with `p`/`b` matching the swept platform and model.
    pub bound: BoundParams,
    /// Parameters per learner; one reduction moves `4 * n_params` bytes.
    pub n_params: usize,
    /// Step horizon T the closed-form costs and bounds are evaluated over.
    pub horizon: u64,
    /// Modelled compute seconds per synchronous step
    /// ([`coordinator::sim_step_seconds`]).
    pub step_seconds: f64,
    /// Heterogeneity the candidates are priced against (`--het` /
    /// `--straggler` on the sweep CLI).  Homogeneous (the default) keeps
    /// the legacy closed-form `compute + comm` makespan; otherwise each
    /// candidate's schedule is replayed through the virtual-time event
    /// engine ([`sim::replay_timeline_stats`]) so frequent wide barriers
    /// pay the straggler tax they would pay in an event-mode run.
    pub het: HetSpec,
    /// Price every static candidate by timeline-only replay
    /// ([`sim::replay_timeline_stats`]) even when the spec is homogeneous
    /// (`sweep --timeline-only`; auto-selected at
    /// P ≥ [`TIMELINE_ONLY_AUTO_P`]).  The replay rides the heap core's
    /// shared step node, so a P = 1,000,000 candidate prices in
    /// microseconds — and the ranking exercises the exact event timeline
    /// a run would see rather than the closed form.
    pub timeline_only: bool,
    /// Preemption regime the candidates are priced against (`sweep
    /// --faults PROB[:mttr]`).  `Some` replaces closed-form pricing with
    /// a fault-armed timeline replay
    /// ([`sim::replay_timeline_stats_faults`]): outages drawn from the
    /// dedicated fault stream of `het.seed` charge lost time and leave
    /// survivor barriers to the remaining group members, so a shape with
    /// frequent wide barriers pays for every learner it would wait out.
    /// Only the sampled spot-preemption form makes sense here — a
    /// scripted trace names learner indices, which don't transfer across
    /// candidate topologies.
    pub faults: Option<FaultSpec>,
}

/// Learner count at or above which the sweep CLI switches to
/// timeline-only pricing automatically (and skips validation runs —
/// training even one candidate at this scale is not what a shape sweep
/// is for).
pub const TIMELINE_ONLY_AUTO_P: usize = 1 << 14;

impl ScoreCtx {
    /// A context for one of the native model registry entries (the same
    /// registry the validation runs execute), default cost model and
    /// bound regime.
    pub fn for_model(
        model: &str,
        p: usize,
        horizon: u64,
        strategy: ReduceStrategy,
        cost: CostModel,
    ) -> Result<ScoreCtx> {
        let Some((dims, batch, eval_batch)) = driver::model_dims(model) else {
            bail!(
                "model {model:?} is not in the native registry (sweep validates natively; have {:?})",
                driver::MODEL_DIMS.iter().map(|m| m.0).collect::<Vec<_>>()
            );
        };
        if horizon == 0 {
            bail!("sweep horizon must be >= 1 step");
        }
        // The backend's layout is the single source of truth for the
        // parameter count (and hence bytes per reduction) — the same
        // backend the validation runs execute.
        let n_params = NativeMlp::new(dims, batch, eval_batch)?.layout().total;
        let mut bound = BoundParams::default();
        bound.p = p as f64;
        bound.b = batch as f64;
        bound.validate()?;
        Ok(ScoreCtx {
            cost,
            strategy,
            bound,
            n_params,
            horizon,
            step_seconds: coordinator::sim_step_seconds(batch, n_params),
            het: HetSpec::default(),
            timeline_only: false,
            faults: None,
        })
    }
}

/// One point of the search space: a topology shape plus its schedule
/// (base intervals and the policy that realizes them).
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Group-size chain, innermost first, last = P.
    pub levels: Vec<usize>,
    /// Per-level link class, parallel to `levels`.
    pub links: Vec<LinkClass>,
    /// Per-level averaging intervals, parallel to `levels`.
    pub ks: Vec<u64>,
    /// How the intervals are realized at run time: static (the closed
    /// form scores it exactly) or a non-static policy (scored by replay).
    pub policy: PolicyKind,
    /// Payload transform the candidate's collectives apply
    /// (`Compression::None` for a dense candidate).
    pub compress: Compression,
}

impl Candidate {
    /// A candidate under the topology's default link assignment
    /// (innermost intra-node, outer levels inter-node) and the static
    /// schedule policy, dense payloads.
    pub fn with_default_links(levels: Vec<usize>, ks: Vec<u64>) -> Result<Candidate> {
        let topo = HierTopology::new(levels.clone())?;
        let links = (0..topo.n_levels()).map(|l| topo.link(l)).collect();
        Ok(Candidate {
            levels,
            links,
            ks,
            policy: PolicyKind::Static,
            compress: Compression::None,
        })
    }

    /// Stable identifier:
    /// `h<sizes>-k<intervals>[-rack][-<policy>][-<compression>]` (the
    /// compression suffix is the canonical spec with its `:` separators
    /// dropped, e.g. `-topk0.05`).
    pub fn label(&self) -> String {
        let sizes: Vec<String> = self.levels.iter().map(|s| s.to_string()).collect();
        let ks: Vec<String> = self.ks.iter().map(|k| k.to_string()).collect();
        let mut s = format!("h{}-k{}", sizes.join("x"), ks.join("_"));
        if self.links.last() == Some(&LinkClass::RackFabric) {
            s.push_str("-rack");
        }
        if self.policy != PolicyKind::Static {
            s.push('-');
            s.push_str(self.policy.name());
        }
        if !self.compress.is_none() {
            s.push('-');
            s.push_str(&self.compress.spec().replace(':', ""));
        }
        s
    }

    pub fn topology(&self) -> Result<HierTopology> {
        HierTopology::with_links(self.levels.clone(), self.links.clone())
    }

    pub fn schedule(&self) -> Result<HierSchedule> {
        HierSchedule::new(self.ks.clone())
    }

    /// The paper's two-level projection used by the theory layer.
    pub fn k1k2s(&self) -> (u64, u64, u64) {
        (self.ks[0], *self.ks.last().unwrap(), self.levels[0] as u64)
    }

    /// A native-backend run configuration for this shape (epochs / data
    /// sizes left at defaults; see [`validation_config`]).
    pub fn to_config(&self, model: &str) -> RunConfig {
        let mut cfg = RunConfig::defaults(model);
        cfg.backend = BackendKind::Native;
        cfg.set_levels(self.levels.clone());
        cfg.set_ks(self.ks.clone());
        cfg.links = self.links.clone();
        cfg.schedule_policy = self.policy;
        cfg.compress = self.compress;
        cfg
    }
}

// ---------------------------------------------------------------------------
// Enumeration
// ---------------------------------------------------------------------------

/// All divisor chains `d_1 < d_2 < … < P` of the given length whose
/// entries each divide the next.  Chains of length ≥ 3 require `d_1 ≥ 2`:
/// a size-1 inner tier is a no-op duplicating the (L−1)-level shape.
fn divisor_chains(p: usize, len: usize) -> Vec<Vec<usize>> {
    let divisors: Vec<usize> = (1..p).filter(|d| p % d == 0).collect();
    let mut out = Vec::new();
    let mut chain = Vec::with_capacity(len);
    fn rec(
        divisors: &[usize],
        p: usize,
        len: usize,
        min: usize,
        chain: &mut Vec<usize>,
        out: &mut Vec<Vec<usize>>,
    ) {
        if chain.len() == len - 1 {
            let mut full = chain.clone();
            full.push(p);
            out.push(full);
            return;
        }
        for &d in divisors {
            if d < min {
                continue;
            }
            if let Some(&prev) = chain.last() {
                if d % prev != 0 {
                    continue;
                }
            }
            chain.push(d);
            rec(divisors, p, len, d + 1, chain, out);
            chain.pop();
        }
    }
    let min = if len >= 3 { 2 } else { 1 };
    rec(&divisors, p, len, min, &mut chain, &mut out);
    out
}

/// Per-shape schedule candidates: for each base K1, a geometric (ratio-2)
/// inner chain, with the outermost interval drawn from {2×, 4×} the last
/// inner interval plus the theory's [`theory::optimal_k2`] point under the
/// condition-(3.5) cap.  With `local_averaging` off, flat single-interval
/// schedules (pure K-AVG).
fn schedules_for(levels: &[usize], space: &SweepSpace, ctx: &ScoreCtx) -> Vec<Vec<u64>> {
    let l = levels.len();
    let s = (levels[0] as u64).max(1);
    let cap = space.k2_cap(&ctx.bound);
    if !space.local_averaging || (l == 2 && levels[0] <= 1) {
        // The K-AVG family: either the whole space is restricted to it
        // (`--no-local`), or this shape's inner tier is a size-1 no-op —
        // any inner interval is then score- and training-equivalent (the
        // S = 1 deviation term Φ is independent of K1), so enumerating
        // one flat representative per outer interval avoids padding the
        // ranking with duplicate-score candidates under distinct labels.
        let mut k2s = space.k1_grid.clone();
        for &k1 in &space.k1_grid {
            if k1 == 0 {
                continue;
            }
            k2s.extend([2 * k1, 4 * k1]);
        }
        k2s.push(theory::optimal_k2(&ctx.bound, ctx.horizon, 1, s, cap.max(1)));
        // `k2_max` caps the outermost interval, fixed continuations
        // included — never enumerate past what the user asked for.
        k2s.retain(|&k| k >= 1 && k <= space.k2_max);
        k2s.sort_unstable();
        k2s.dedup();
        return k2s.into_iter().map(|k| vec![k; l]).collect();
    }
    let mut out: Vec<Vec<u64>> = Vec::new();
    for &k1 in &space.k1_grid {
        if k1 == 0 {
            continue;
        }
        let inner: Vec<u64> = (0..l - 1).map(|i| k1 << i).collect();
        let last_inner = *inner.last().unwrap_or(&k1);
        let opt =
            theory::optimal_k2(&ctx.bound, ctx.horizon, last_inner, s, cap.max(last_inner));
        let mut outers = vec![2 * last_inner, 4 * last_inner, opt.max(last_inner)];
        // Honor the user's K2 cap on the fixed {2x, 4x} continuations too
        // (a chain whose last inner interval already exceeds the cap
        // yields no schedule — correctly, since any valid outer would
        // break it).
        outers.retain(|&o| o <= space.k2_max);
        outers.sort_unstable();
        outers.dedup();
        for o in outers {
            let mut ks = inner.clone();
            ks.push(o);
            out.push(ks);
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Enumerate every candidate of the space: shapes × link assignments ×
/// schedules.  Deterministic order (no RNG anywhere in the planner);
/// expects a [`SweepSpace::validate`]d space (a contradictory range just
/// yields no candidates here — [`rank`] rejects it with a real error).
pub fn enumerate(space: &SweepSpace, ctx: &ScoreCtx) -> Vec<Candidate> {
    let mut shapes: Vec<Vec<usize>> = Vec::new();
    if space.local_averaging {
        for len in space.min_levels..=space.max_levels {
            shapes.extend(divisor_chains(space.p, len));
        }
    } else {
        shapes.push(vec![1, space.p]);
    }
    let mut out = Vec::new();
    for shape in shapes {
        for ks in schedules_for(&shape, space, ctx) {
            let Ok(cand) = Candidate::with_default_links(shape.clone(), ks.clone()) else {
                continue;
            };
            if space.use_rack && shape.len() >= 3 {
                let mut rack = cand.clone();
                *rack.links.last_mut().unwrap() = LinkClass::RackFabric;
                out.push(rack);
            }
            out.push(cand);
        }
    }
    // Non-static policies ride next to their static twins: same shapes,
    // same base intervals, scored by replay instead of the closed form.
    if space.policy != PolicyKind::Static {
        let variants: Vec<Candidate> = out
            .iter()
            .map(|c| Candidate { policy: space.policy, ..c.clone() })
            .collect();
        out.extend(variants);
    }
    // Compressed payloads ride next to *every* dense entry (policy
    // variants included): same shape, same schedule, smaller wire
    // payload — the joint (topology × schedule × compression) space the
    // ranking orders.
    if !space.compress.is_empty() {
        let dense: Vec<Candidate> = out.clone();
        for &comp in &space.compress {
            if comp.is_none() {
                continue; // validate() rejects this; belt and braces
            }
            out.extend(
                dense.iter().map(|c| Candidate { compress: comp, ..c.clone() }),
            );
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Scoring
// ---------------------------------------------------------------------------

/// Per-level slice of a candidate's modelled communication cost over the
/// horizon, mirroring the engine's [`crate::comm::LevelStats`] accounting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LevelCost {
    pub level: usize,
    pub size: usize,
    pub link: LinkClass,
    /// Schedule events at this level over the horizon.
    pub events: u64,
    /// Group reductions fired (events × groups; 0 for size-1 levels below
    /// the top, which the engine skips as no-ops).
    pub reductions: u64,
    pub bytes: u64,
    pub seconds: f64,
}

/// A candidate's modelled cost + convergence figures.
#[derive(Debug, Clone, PartialEq)]
pub struct Score {
    /// Modelled communication seconds over the horizon (per-level events ×
    /// one symmetric group's allreduce — the engine's concurrent-groups
    /// convention).
    pub comm_seconds: f64,
    /// Total bytes crossing the network over the horizon.
    pub comm_bytes: u64,
    /// Modelled compute seconds over the horizon (base rate).
    pub compute_seconds: f64,
    /// Straggler-aware modelled wall clock over the horizon: equal to
    /// `compute + comm` under homogeneous compute, otherwise the makespan
    /// of the candidate's schedule replayed through the event timeline
    /// (heterogeneous rates + seeded straggler spikes).
    pub makespan_seconds: f64,
    /// Fixed-budget convergence bound B(K1, K2, S) of Theorem 3.4 — for
    /// a non-static candidate, evaluated at the interval table its
    /// policy replay *settled on* (the schedule it actually realized),
    /// not the base table.
    pub bound: f64,
    /// Whether the (realized, for non-static) K2 satisfies step-size
    /// condition (3.5).
    pub condition_35: bool,
    /// `(compute + comm) × bound / bound_floor`; filled by [`rank`]
    /// (NaN straight out of [`score`]).
    pub time_to_target: f64,
    pub levels: Vec<LevelCost>,
}

/// Cost + bound for one candidate over `ctx.horizon` steps: the exact
/// closed form for static candidates, a policy replay through the
/// virtual-time event engine for non-static ones (the realized event
/// counts — not the interval table — price the communication, and the
/// replay's makespan prices the wall clock; deterministic, because the
/// policy's only input is the seeded timeline).
pub fn score(cand: &Candidate, ctx: &ScoreCtx) -> Result<Score> {
    let topo = cand.topology()?;
    let sched = cand.schedule()?;
    if topo.n_levels() != sched.n_levels() {
        bail!(
            "candidate {} has {} intervals for {} levels",
            cand.label(),
            sched.n_levels(),
            topo.n_levels()
        );
    }
    // The candidate's wire payload: dense gradients move 4·n_params
    // bytes; a compressed candidate moves `Compression::payload_bytes`
    // — the same quantity the engine's reducer prices a compressed run
    // with, so modelled-vs-measured parity holds for compressed
    // candidates too (`Compression::None` is exactly 4·n_params, keeping
    // dense scores bit-stable).
    let msg = cand.compress.payload_bytes(ctx.n_params);
    // Per-level unit costs under the engine's reduce_level conventions:
    // size-1 levels below the top are no-ops; otherwise every group
    // counts its event and bytes, but symmetric groups run concurrently
    // so the level is charged one group's seconds per event.
    let mut sec_per_events = Vec::with_capacity(topo.n_levels());
    let mut bytes_per_groups = Vec::with_capacity(topo.n_levels());
    let mut groups_per_level = Vec::with_capacity(topo.n_levels());
    for l in 0..topo.n_levels() {
        let size = topo.size(l);
        let (sec_per_event, bytes_per_group, groups) =
            if size <= 1 && l + 1 < topo.n_levels() {
                (0.0, 0u64, 0u64)
            } else {
                (
                    ctx.cost.allreduce_seconds(size, msg, topo.link(l), ctx.strategy),
                    ctx.cost.allreduce_bytes(size, msg, ctx.strategy),
                    topo.n_groups(l) as u64,
                )
            };
        sec_per_events.push(sec_per_event);
        bytes_per_groups.push(bytes_per_group);
        groups_per_level.push(groups);
    }
    // Event counts + makespan: closed form for static, replay otherwise.
    // For a replayed policy the *final* interval table also feeds the
    // convergence bound below — an adaptive candidate that widened K2 up
    // to the clamp must be ranked with the budget of the schedule it
    // actually realized, not the tighter bound of its base table
    // (otherwise every adaptive twin would beat its static twin by
    // pairing a smaller makespan with an unearned bound).
    let (counts, replay_makespan, realized_intervals) =
        if cand.policy == PolicyKind::Static {
            (sched.reduction_counts(ctx.horizon), None, None)
        } else {
            let clamp = theory::max_k2_condition_35(&ctx.bound, K2_CLAMP_CAP).unwrap_or(1);
            let mut policy = cand.policy.build(clamp, ctx.step_seconds, topo.p());
            let mut model =
                sim::EventModel::new(topo.p(), topo.n_levels(), ctx.step_seconds, &ctx.het);
            if let Some(spec) = ctx.faults {
                use crate::sim::ExecModel;
                model.install_faults(ctx.het.seed, &FaultPlan::Sampled(spec));
            }
            let realized = sim::drive_timeline_policy(
                &mut model,
                &topo,
                policy.as_mut(),
                &sched,
                ctx.horizon,
                &sec_per_events,
            );
            let final_intervals = policy.intervals(&sched);
            (realized, Some(model.breakdown().makespan_seconds), Some(final_intervals))
        };
    let mut levels = Vec::with_capacity(topo.n_levels());
    let mut comm_seconds = 0.0f64;
    let mut comm_bytes = 0u64;
    for l in 0..topo.n_levels() {
        let events = counts[l];
        let seconds = events as f64 * sec_per_events[l];
        // events × groups × bytes overflows u64 around P ~ 10^6 with long
        // horizons; a silently wrapped byte total would corrupt the
        // ranking, so fail loudly with the knobs that caused it.
        let bytes = events
            .checked_mul(groups_per_level[l])
            .and_then(|x| x.checked_mul(bytes_per_groups[l]))
            .and_then(|b| comm_bytes.checked_add(b).map(|_| b))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "candidate {}: modelled comm bytes overflow u64 at level {l} \
                     ({events} events x {} groups x {} bytes/group) — reduce the \
                     horizon ({}) or the learner count ({})",
                    cand.label(),
                    groups_per_level[l],
                    bytes_per_groups[l],
                    ctx.horizon,
                    topo.p()
                )
            })?;
        comm_seconds += seconds;
        comm_bytes += bytes;
        levels.push(LevelCost {
            level: l,
            size: topo.size(l),
            link: topo.link(l),
            events,
            reductions: events * groups_per_level[l],
            bytes,
            seconds,
        });
    }
    let (k1, k2, s) = cand.k1k2s();
    let (k1, k2) = match &realized_intervals {
        Some(iv) => (iv[0], *iv.last().unwrap()),
        None => (k1, k2),
    };
    // Compression noise inflates the bound's gradient-variance term M:
    // what a lossy payload drops each round re-enters Thm 3.4 as extra
    // stochastic noise (δ-contraction model, `Compression::
    // variance_inflation`), so a `sweep --compress` variant pays its
    // accuracy cost in the ranking instead of riding the dense bound with
    // a smaller payload.  `Compression::None` inflates by exactly 1.0,
    // keeping dense scores bit-stable.
    let mut bp = ctx.bound;
    bp.m *= cand.compress.variance_inflation();
    let bound = theory::thm34_budget_bound(&bp, ctx.horizon, k1, k2, s.max(1));
    let compute_seconds = ctx.horizon as f64 * ctx.step_seconds;
    // Static + homogeneous compute keeps the exact closed form
    // (bit-stable with the pre-event-engine ranking) unless the context
    // asks for timeline-only pricing; heterogeneous or timeline-only
    // contexts replay the schedule through the virtual timeline — the
    // stats form, which never materializes O(P) breakdown vectors, so a
    // million-learner candidate prices in microseconds on the heap
    // core's shared step node (and in one flat pooled pass under
    // heterogeneity).  Non-static candidates always use their replay's
    // makespan (its stepwise accumulation is exactly what a live engine
    // run's timeline reports — the validation parity).
    // Known optimization if het sweeps ever feel slow: the per-learner
    // step-duration stream depends only on (P, het, seed) — one duration
    // matrix could be precomputed per ScoreCtx and shared across
    // candidates, leaving only the O(horizon·P) barrier walk per replay.
    let makespan_seconds = match (replay_makespan, ctx.faults) {
        (Some(m), _) => m,
        // A fault regime always prices through the timeline: preempted
        // learners charge lost time the closed form cannot see.
        (None, Some(spec)) => {
            // Degraded groups are repriced at the survivor participant
            // count over the *dense* payload — the engine's
            // `Reducer::survivor_group` never compresses a degraded
            // barrier, and the replay mirrors that rule exactly.
            let survivor = |level: usize, n_part: usize| {
                ctx.cost.allreduce_seconds(n_part, ctx.n_params * 4, topo.link(level), ctx.strategy)
            };
            sim::replay_timeline_stats_faults(
                &topo,
                &sched,
                ctx.horizon,
                ctx.step_seconds,
                &sec_per_events,
                &ctx.het,
                &FaultPlan::Sampled(spec),
                &survivor,
            )
            .makespan_seconds
        }
        (None, None) if ctx.het.is_homogeneous() && !ctx.timeline_only => {
            compute_seconds + comm_seconds
        }
        (None, None) => {
            sim::replay_timeline_stats(
                &topo,
                &sched,
                ctx.horizon,
                ctx.step_seconds,
                &sec_per_events,
                &ctx.het,
            )
            .makespan_seconds
        }
    };
    Ok(Score {
        comm_seconds,
        comm_bytes,
        compute_seconds,
        makespan_seconds,
        bound,
        condition_35: ctx.bound.condition_35(k2),
        time_to_target: f64::NAN,
        levels,
    })
}

/// A scored candidate in the ranking.
#[derive(Debug, Clone)]
pub struct Ranked {
    pub candidate: Candidate,
    pub score: Score,
}

/// Enumerate, score, and rank the space by modelled time-to-target
/// (ascending = better).  Ties break on communication seconds, then on
/// the candidate label, so the order is fully deterministic.
pub fn rank(space: &SweepSpace, ctx: &ScoreCtx) -> Result<Vec<Ranked>> {
    space.validate()?;
    let cands = enumerate(space, ctx);
    if cands.is_empty() {
        bail!("empty search space for p={}", space.p);
    }
    let mut ranked = cands
        .into_iter()
        .map(|candidate| {
            let score = score(&candidate, ctx)?;
            Ok(Ranked { candidate, score })
        })
        .collect::<Result<Vec<_>>>()?;
    let floor = ranked.iter().map(|r| r.score.bound).fold(f64::INFINITY, f64::min);
    for r in &mut ranked {
        r.score.time_to_target = r.score.makespan_seconds * (r.score.bound / floor);
    }
    ranked.sort_by(|a, b| {
        a.score
            .time_to_target
            .partial_cmp(&b.score.time_to_target)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| {
                a.score
                    .comm_seconds
                    .partial_cmp(&b.score.comm_seconds)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .then_with(|| a.candidate.label().cmp(&b.candidate.label()))
    });
    Ok(ranked)
}

// ---------------------------------------------------------------------------
// Validation: replay the top candidates through the real engine
// ---------------------------------------------------------------------------

/// Steps per epoch of a validation run (short but long enough for inner
/// tiers to fire many times; outer intervals past `2 × VALIDATION_EPOCHS ×
/// this` simply record zero events, consistently on both sides of the
/// comparison).
const VALIDATION_SPE: usize = 24;
const VALIDATION_EPOCHS: usize = 2;

/// The short deterministic run a candidate is validated with: native
/// backend, fixed seed, constant LR, trace recording on.  This is also the
/// scenario generator the golden-trace suite feeds on
/// (rust/tests/golden_trace.rs).
pub fn validation_config(
    cand: &Candidate,
    model: &str,
    collective: CollectiveKind,
) -> Result<RunConfig> {
    let Some((_, batch, eval_batch)) = driver::model_dims(model) else {
        bail!("model {model:?} is not in the native registry");
    };
    let mut cfg = cand.to_config(model);
    cfg.collective = collective;
    cfg.epochs = VALIDATION_EPOCHS;
    cfg.train_n = VALIDATION_SPE * cfg.p * batch;
    cfg.test_n = eval_batch;
    cfg.lr = LrSchedule::Constant(0.05);
    cfg.record_trace = true;
    cfg.validate()?;
    Ok(cfg)
}

/// Run a validation config end to end with an explicitly seeded
/// initialization, bypassing the artifact manifest: validation runs are
/// calibration probes and must be bit-reproducible on any checkout,
/// whether or not `make artifacts` has been run.
pub fn validation_record(cfg: &RunConfig) -> Result<RunRecord> {
    let Some((dims, batch, eval_batch)) = driver::model_dims(&cfg.model) else {
        bail!("model {:?} is not in the native registry", cfg.model);
    };
    let backend = NativeMlp::new(dims, batch, eval_batch)?;
    // Same data wiring as driver::build (shared spec builder); only the
    // init path differs — explicitly seeded instead of the artifact blob.
    let data = ClassifyData::generate(driver::mixture_spec(cfg, dims));
    let init = backend.init(&mut Pcg32::seeded(cfg.seed));
    Trainer::new(cfg, Box::new(backend), Box::new(data), init)?.run()
}

/// Modelled-vs-measured comparison for one candidate.
#[derive(Debug, Clone)]
pub struct Validation {
    pub label: String,
    pub total_steps: u64,
    /// Closed-form communication seconds at the run's actual step count.
    pub modelled_comm_seconds: f64,
    /// The engine's accounted communication seconds for the same run.
    pub measured_comm_seconds: f64,
    /// measured − modelled (near-zero by construction; drift = regression).
    pub delta_seconds: f64,
    pub modelled_level_seconds: Vec<f64>,
    pub measured_level_seconds: Vec<f64>,
    pub modelled_comm_bytes: u64,
    pub measured_comm_bytes: u64,
    /// The score's makespan at the run's actual step count — the quantity
    /// the ranking orders by.
    pub modelled_makespan_seconds: f64,
    /// The run's own timeline makespan.  Heterogeneous validations run
    /// `--exec event` under the sweep's het spec, so a drift between
    /// `sim::replay_timeline` and the engine's timeline shows up here.
    pub measured_makespan_seconds: f64,
    /// measured − modelled makespan (near-zero by construction).
    pub makespan_delta_seconds: f64,
    pub final_train_loss: f64,
    pub final_test_acc: f64,
}

/// Validate one candidate: run it, then re-score at the measured horizon
/// so the closed form and the engine are compared like for like.  `ctx`
/// must have been built for the same `model` (same n_params).
pub fn validate(
    cand: &Candidate,
    ctx: &ScoreCtx,
    model: &str,
    collective: CollectiveKind,
) -> Result<Validation> {
    let mut cfg = validation_config(cand, model, collective)?;
    // The run must charge reductions with the same strategy and α–β
    // parameters the closed form scores with, or the modelled-vs-measured
    // delta would be spurious for non-default `--strategy`/cost settings.
    cfg.strategy = ctx.strategy;
    cfg.cost = ctx.cost;
    // A heterogeneous or fault-armed sweep ranks by the event timeline's
    // makespan, so the validation run must execute under the same event
    // model, het spec, and fault regime (seed included — the run's
    // straggler and fault streams derive from cfg.seed), or the quantity
    // driving the ranking would never be checked against a measured run.
    if !ctx.het.is_homogeneous() || ctx.faults.is_some() {
        cfg.exec = crate::sim::ExecKind::Event;
        cfg.set_het_spec(&ctx.het);
        cfg.faults = ctx.faults.map(FaultPlan::Sampled);
        cfg.validate()?;
    }
    let rec = validation_record(&cfg)?;
    let vctx = ScoreCtx { horizon: rec.total_steps.max(1), ..*ctx };
    let vscore = score(cand, &vctx)?;
    let measured_comm_seconds = rec.comm.total_seconds();
    let measured_comm_bytes =
        rec.comm.local_bytes + rec.comm.global_bytes + rec.comm.rack_bytes;
    Ok(Validation {
        label: cand.label(),
        total_steps: rec.total_steps,
        modelled_comm_seconds: vscore.comm_seconds,
        measured_comm_seconds,
        delta_seconds: measured_comm_seconds - vscore.comm_seconds,
        modelled_level_seconds: vscore.levels.iter().map(|l| l.seconds).collect(),
        measured_level_seconds: rec.comm_levels.iter().map(|l| l.seconds).collect(),
        modelled_comm_bytes: vscore.comm_bytes,
        measured_comm_bytes,
        modelled_makespan_seconds: vscore.makespan_seconds,
        measured_makespan_seconds: rec.makespan_seconds,
        makespan_delta_seconds: rec.makespan_seconds - vscore.makespan_seconds,
        final_train_loss: rec.final_train_loss(),
        final_test_acc: rec.final_test_acc(),
    })
}

/// Validate the first `n` entries of a ranking.
pub fn validate_top(
    ranked: &[Ranked],
    ctx: &ScoreCtx,
    model: &str,
    n: usize,
    collective: CollectiveKind,
) -> Result<Vec<Validation>> {
    ranked
        .iter()
        .take(n)
        .map(|r| validate(&r.candidate, ctx, model, collective))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx16() -> ScoreCtx {
        ScoreCtx::for_model("quickstart", 16, 20_000, ReduceStrategy::Ring, CostModel::default())
            .unwrap()
    }

    #[test]
    fn divisor_chains_are_valid() {
        for len in 2..=4 {
            for chain in divisor_chains(16, len) {
                assert_eq!(chain.len(), len);
                assert_eq!(*chain.last().unwrap(), 16);
                for w in chain.windows(2) {
                    assert!(w[0] < w[1] && w[1] % w[0] == 0, "{chain:?}");
                }
                if len >= 3 {
                    assert!(chain[0] >= 2, "{chain:?}");
                }
            }
        }
        assert_eq!(divisor_chains(16, 2).len(), 4); // s in {1,2,4,8}
        assert_eq!(divisor_chains(16, 3).len(), 3); // (2,4) (2,8) (4,8)
        assert_eq!(divisor_chains(16, 4).len(), 1); // (2,4,8)
    }

    #[test]
    fn enumeration_is_deterministic_and_valid() {
        let space = SweepSpace::new(16).unwrap();
        let ctx = ctx16();
        let a = enumerate(&space, &ctx);
        let b = enumerate(&space, &ctx);
        assert_eq!(a, b);
        assert!(a.len() >= 20, "only {} candidates", a.len());
        for c in &a {
            c.topology().unwrap();
            c.schedule().unwrap();
            assert_eq!(*c.levels.last().unwrap(), 16);
            assert_eq!(c.levels.len(), c.ks.len());
            assert_eq!(c.levels.len(), c.links.len());
        }
    }

    #[test]
    fn rack_variants_present_only_for_deep_shapes() {
        let space = SweepSpace::new(16).unwrap();
        let ctx = ctx16();
        for c in enumerate(&space, &ctx) {
            let has_rack = c.links.contains(&LinkClass::RackFabric);
            if c.levels.len() < 3 {
                assert!(!has_rack, "{}", c.label());
            }
            if has_rack {
                assert_eq!(*c.links.last().unwrap(), LinkClass::RackFabric);
            }
        }
        let mut no_rack = space.clone();
        no_rack.use_rack = false;
        for c in enumerate(&no_rack, &ctx) {
            assert!(!c.links.contains(&LinkClass::RackFabric));
        }
    }

    #[test]
    fn score_matches_hand_computation_two_level() {
        // [4, 16], ks [2, 8] over 64 steps: 24 inner events (t%2 & !%8),
        // 8 outer events.
        let ctx = ScoreCtx { horizon: 64, ..ctx16() };
        let cand = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
        let s = score(&cand, &ctx).unwrap();
        let msg = ctx.n_params * 4;
        let inner =
            ctx.cost.allreduce_seconds(4, msg, LinkClass::IntraNode, ctx.strategy);
        let outer =
            ctx.cost.allreduce_seconds(16, msg, LinkClass::InterNode, ctx.strategy);
        assert_eq!(s.levels[0].events, 24);
        assert_eq!(s.levels[1].events, 8);
        assert_eq!(s.levels[0].reductions, 24 * 4);
        assert_eq!(s.levels[1].reductions, 8);
        assert!((s.comm_seconds - (24.0 * inner + 8.0 * outer)).abs() < 1e-12);
        assert!(s.bound.is_finite() && s.bound > 0.0);
    }

    #[test]
    fn timeline_only_matches_closed_form_under_homogeneity() {
        // Pricing through the shared-step-node replay instead of the
        // closed form must not move a homogeneous candidate's score:
        // same makespan (to fp tolerance), identical comm account.
        let ctx = ScoreCtx { horizon: 64, ..ctx16() };
        let tctx = ScoreCtx { timeline_only: true, ..ctx };
        let cand = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
        let closed = score(&cand, &ctx).unwrap();
        let replayed = score(&cand, &tctx).unwrap();
        assert!(
            (replayed.makespan_seconds - closed.makespan_seconds).abs()
                <= 1e-9 * closed.makespan_seconds,
            "{} vs {}",
            replayed.makespan_seconds,
            closed.makespan_seconds
        );
        assert_eq!(replayed.comm_bytes, closed.comm_bytes);
        assert_eq!(replayed.comm_seconds.to_bits(), closed.comm_seconds.to_bits());
    }

    #[test]
    fn size_one_inner_level_is_free() {
        let ctx = ScoreCtx { horizon: 64, ..ctx16() };
        let cand = Candidate::with_default_links(vec![1, 16], vec![4, 4]).unwrap();
        let s = score(&cand, &ctx).unwrap();
        assert_eq!(s.levels[0].seconds, 0.0);
        assert_eq!(s.levels[0].reductions, 0);
        assert_eq!(s.levels[0].events, 0); // flat schedule: outer subsumes
        assert_eq!(s.levels[1].events, 16);
    }

    #[test]
    fn rank_is_sorted_and_finite() {
        let space = SweepSpace::new(16).unwrap();
        let ranked = rank(&space, &ctx16()).unwrap();
        assert!(ranked.len() >= 20);
        for w in ranked.windows(2) {
            assert!(w[0].score.time_to_target <= w[1].score.time_to_target);
        }
        for r in &ranked {
            assert!(r.score.time_to_target.is_finite() && r.score.time_to_target > 0.0);
            assert!(r.score.bound.is_finite() && r.score.bound > 0.0);
        }
    }

    #[test]
    fn no_local_space_is_kavg_family() {
        let mut space = SweepSpace::new(16).unwrap();
        space.local_averaging = false;
        let ranked = rank(&space, &ctx16()).unwrap();
        assert!(!ranked.is_empty());
        for r in &ranked {
            assert_eq!(r.candidate.levels, vec![1, 16]);
            let (k1, k2, s) = r.candidate.k1k2s();
            assert_eq!(k1, k2, "flat schedule expected: {}", r.candidate.label());
            assert_eq!(s, 1);
        }
    }

    #[test]
    fn optimal_k2_schedules_respect_condition_cap() {
        let space = SweepSpace::new(16).unwrap();
        let ctx = ctx16();
        let cap = space.k2_cap(&ctx.bound);
        // Every enumerated K2 beyond the fixed {2x, 4x} continuations must
        // come from optimal_k2, hence sit within the cap.
        for c in enumerate(&space, &ctx) {
            let (_, k2, _) = c.k1k2s();
            let last_inner = c.ks[c.ks.len() - 2];
            if k2 != 2 * last_inner && k2 != 4 * last_inner {
                assert!(k2 <= cap.max(last_inner), "{} k2={k2} cap={cap}", c.label());
            }
        }
    }

    #[test]
    fn k2_max_caps_every_enumerated_outer_interval() {
        let mut space = SweepSpace::new(16).unwrap();
        space.k2_max = 8;
        let ranked = rank(&space, &ctx16()).unwrap();
        assert!(!ranked.is_empty());
        for r in &ranked {
            let (_, k2, _) = r.candidate.k1k2s();
            assert!(k2 <= 8, "{} exceeds --k2-max", r.candidate.label());
        }
    }

    #[test]
    fn homogeneous_makespan_is_the_legacy_sum() {
        let ctx = ScoreCtx { horizon: 256, ..ctx16() };
        let cand = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
        let s = score(&cand, &ctx).unwrap();
        assert_eq!(
            s.makespan_seconds.to_bits(),
            (s.compute_seconds + s.comm_seconds).to_bits(),
            "homogeneous scoring must stay bit-stable with the pre-event ranking"
        );
    }

    #[test]
    fn straggler_aware_makespan_prices_barrier_waits() {
        let mut ctx = ScoreCtx { horizon: 512, ..ctx16() };
        ctx.het = HetSpec { het: 0.3, straggler_prob: 0.1, straggler_mult: 4.0, seed: 7 };
        let cand = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
        let s = score(&cand, &ctx).unwrap();
        // Heterogeneous learners can only extend the timeline: the slowest
        // learner's busy time alone exceeds the base compute.
        assert!(
            s.makespan_seconds > s.compute_seconds + s.comm_seconds,
            "makespan {} vs sum {}",
            s.makespan_seconds,
            s.compute_seconds + s.comm_seconds
        );
        // ... deterministically (same seed, same bits).
        let s2 = score(&cand, &ctx).unwrap();
        assert_eq!(s.makespan_seconds.to_bits(), s2.makespan_seconds.to_bits());
        // Ranking under heterogeneity stays fully ordered and finite.
        let space = SweepSpace::new(16).unwrap();
        let ranked = rank(&space, &ctx).unwrap();
        for w in ranked.windows(2) {
            assert!(w[0].score.time_to_target <= w[1].score.time_to_target);
        }
        for r in &ranked {
            assert!(r.score.makespan_seconds.is_finite() && r.score.makespan_seconds > 0.0);
        }
    }

    #[test]
    fn stragglers_tax_frequent_global_schedules_hardest() {
        // The event-engine advantage the planner must see: under random
        // spikes, a sync-SGD-like schedule pays max-over-P spikes at every
        // step, while a sparse-global schedule lets spikes average out
        // between barriers.  Relative inflation must order that way.
        let mut ctx = ScoreCtx { horizon: 512, ..ctx16() };
        ctx.het = HetSpec { het: 0.0, straggler_prob: 0.2, straggler_mult: 3.0, seed: 11 };
        let inflation = |ks: Vec<u64>| {
            let cand = Candidate::with_default_links(vec![1, 16], ks).unwrap();
            let s = score(&cand, &ctx).unwrap();
            s.makespan_seconds / (s.compute_seconds + s.comm_seconds)
        };
        let sync = inflation(vec![1, 1]);
        let sparse = inflation(vec![1, 32]);
        assert!(sync > sparse, "sync inflation {sync} vs sparse {sparse}");
    }

    #[test]
    fn validation_measures_the_makespan_the_ranking_orders_by() {
        let cand = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
        // Homogeneous: the lockstep run's step-accumulated clock must agree
        // with the closed-form compute + comm sum (to fp-accumulation
        // tolerance).
        let hom_ctx = ctx16();
        let hom = validate(&cand, &hom_ctx, "quickstart", CollectiveKind::Simulated).unwrap();
        assert!(hom.measured_makespan_seconds > 0.0);
        let rel = hom.makespan_delta_seconds.abs() / hom.measured_makespan_seconds;
        assert!(
            rel < 1e-9,
            "homogeneous makespan drift: modelled {} vs measured {}",
            hom.modelled_makespan_seconds,
            hom.measured_makespan_seconds
        );
        // Heterogeneous: the validation run executes under the event model
        // with the sweep's het spec, so replay_timeline and the engine's
        // timeline walk the identical call sequence — a barrier-rule or
        // level-indexing drift between them shows up as a nonzero delta.
        let mut het_ctx = ctx16();
        het_ctx.het =
            HetSpec { het: 0.3, straggler_prob: 0.05, straggler_mult: 4.0, seed: 13 };
        let het = validate(&cand, &het_ctx, "quickstart", CollectiveKind::Simulated).unwrap();
        let rel = het.makespan_delta_seconds.abs() / het.measured_makespan_seconds;
        assert!(
            rel < 1e-9,
            "het makespan drift: modelled {} vs measured {}",
            het.modelled_makespan_seconds,
            het.measured_makespan_seconds
        );
        // ... and the het makespan genuinely exceeds the homogeneous one.
        assert!(het.measured_makespan_seconds > hom.measured_makespan_seconds);
        // Comm parity still holds under the event model (time model only).
        let rel = het.delta_seconds.abs() / het.measured_comm_seconds.max(1e-30);
        assert!(rel < 1e-9, "het comm drift {rel}");
    }

    #[test]
    fn policy_variants_ride_next_to_static_entries() {
        let mut space = SweepSpace::new(16).unwrap();
        space.policy = PolicyKind::Adaptive { target: 0.25, gain: 1.0 };
        // Short horizon: every adaptive candidate is priced by an
        // O(horizon · P) replay, and this test ranks the space twice.
        let ctx = ScoreCtx { horizon: 2_000, ..ctx16() };
        let cands = enumerate(&space, &ctx);
        let n_static = cands.iter().filter(|c| c.policy == PolicyKind::Static).count();
        let n_adaptive = cands.len() - n_static;
        assert_eq!(n_static, n_adaptive, "every shape needs both variants");
        // Labels distinguish the twins.
        let adaptive = cands.iter().find(|c| c.policy != PolicyKind::Static).unwrap();
        assert!(adaptive.label().ends_with("-adaptive"), "{}", adaptive.label());
        // ... and the whole space still ranks deterministically.
        let a = rank(&space, &ctx).unwrap();
        let b = rank(&space, &ctx).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.candidate, y.candidate);
            assert_eq!(
                x.score.makespan_seconds.to_bits(),
                y.score.makespan_seconds.to_bits()
            );
        }
    }

    #[test]
    fn adaptive_replay_scoring_thins_global_events_under_stragglers() {
        let mut ctx = ScoreCtx { horizon: 2_048, ..ctx16() };
        ctx.het = HetSpec { het: 0.6, straggler_prob: 0.1, straggler_mult: 4.0, seed: 7 };
        let levels = vec![4usize, 16];
        let ks = vec![2u64, 8];
        let stat = Candidate::with_default_links(levels.clone(), ks.clone()).unwrap();
        let mut adap = stat.clone();
        adap.policy = PolicyKind::Adaptive { target: 0.05, gain: 1.0 };
        let s_stat = score(&stat, &ctx).unwrap();
        let s_adap = score(&adap, &ctx).unwrap();
        // The controller widens the straggler-taxed tiers: fewer realized
        // outer events than the static table fires, never more.
        assert!(
            s_adap.levels[1].events < s_stat.levels[1].events,
            "adaptive {} vs static {} outer events",
            s_adap.levels[1].events,
            s_stat.levels[1].events
        );
        assert!(s_adap.comm_seconds < s_stat.comm_seconds);
        assert!(s_adap.makespan_seconds.is_finite() && s_adap.makespan_seconds > 0.0);
        // Warmup goes the other way: dense early averaging adds events.
        let mut warm = stat.clone();
        warm.policy = PolicyKind::Warmup { stage_steps: 64 };
        let s_warm = score(&warm, &ctx).unwrap();
        let total = |s: &Score| s.levels.iter().map(|l| l.events).sum::<u64>();
        assert!(total(&s_warm) > total(&s_stat));
    }

    #[test]
    fn adaptive_validation_measures_what_the_replay_modelled() {
        // The engine run and the scoring replay must co-evolve: same
        // decisions, same realized events, so modelled-vs-measured comm
        // and makespan agree for a *policy-driven* candidate too.
        let mut ctx = ctx16();
        ctx.het = HetSpec { het: 0.5, straggler_prob: 0.1, straggler_mult: 4.0, seed: 13 };
        let mut cand = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
        cand.policy = PolicyKind::Adaptive { target: 0.05, gain: 1.0 };
        let v = validate(&cand, &ctx, "quickstart", CollectiveKind::Simulated).unwrap();
        let rel = v.delta_seconds.abs() / v.measured_comm_seconds.max(1e-30);
        assert!(
            rel < 1e-9,
            "adaptive comm drift: modelled {} vs measured {}",
            v.modelled_comm_seconds,
            v.measured_comm_seconds
        );
        assert_eq!(v.modelled_comm_bytes, v.measured_comm_bytes);
        let rel = v.makespan_delta_seconds.abs() / v.measured_makespan_seconds.max(1e-30);
        assert!(
            rel < 1e-9,
            "adaptive makespan drift: modelled {} vs measured {}",
            v.modelled_makespan_seconds,
            v.measured_makespan_seconds
        );
    }

    #[test]
    fn fault_aware_scoring_prices_preemptions() {
        let ctx = ScoreCtx { horizon: 512, ..ctx16() };
        let cand = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
        let baseline = score(&cand, &ctx).unwrap();
        // An armed fault regime charges lost time the closed form cannot
        // see: the makespan strictly exceeds compute + comm.
        let fctx = ScoreCtx {
            faults: Some(FaultSpec { prob: 0.02, mttr: 10 }),
            ..ctx
        };
        let s = score(&cand, &fctx).unwrap();
        assert!(
            s.makespan_seconds > baseline.makespan_seconds,
            "fault-armed makespan {} vs baseline {}",
            s.makespan_seconds,
            baseline.makespan_seconds
        );
        // ... deterministically (same seed, same bits), and without
        // touching the communication account: the comm seconds/bytes
        // columns keep the closed-form full-group totals, while only the
        // makespan reprices degraded barriers at the survivor count (see
        // replay_timeline_stats_faults).
        let s2 = score(&cand, &fctx).unwrap();
        assert_eq!(s.makespan_seconds.to_bits(), s2.makespan_seconds.to_bits());
        assert_eq!(s.comm_seconds.to_bits(), baseline.comm_seconds.to_bits());
        assert_eq!(s.comm_bytes, baseline.comm_bytes);
        // A zero-probability regime arms the layer but draws no outages:
        // its price matches the plain timeline replay (and hence the
        // closed form, to fp-accumulation tolerance).
        let zctx = ScoreCtx {
            faults: Some(FaultSpec { prob: 0.0, mttr: 10 }),
            ..ctx
        };
        let z = score(&cand, &zctx).unwrap();
        assert!(
            (z.makespan_seconds - baseline.makespan_seconds).abs()
                <= 1e-9 * baseline.makespan_seconds,
            "zero-prob fault pricing drifted: {} vs {}",
            z.makespan_seconds,
            baseline.makespan_seconds
        );
        // Ranking under a fault regime stays fully ordered and finite.
        let space = SweepSpace::new(16).unwrap();
        let ranked = rank(&space, &fctx).unwrap();
        for w in ranked.windows(2) {
            assert!(w[0].score.time_to_target <= w[1].score.time_to_target);
        }
        for r in &ranked {
            assert!(r.score.makespan_seconds.is_finite() && r.score.makespan_seconds > 0.0);
        }
    }

    #[test]
    fn fault_aware_validation_matches_the_survivor_priced_engine() {
        // Modelled-vs-measured parity under an armed fault regime: the
        // replay reprices degraded barriers at the survivor participant
        // count, which is exactly what the engine's
        // `reduce_level_survivors` charges — so the fault-armed makespan
        // the ranking orders by is the makespan a run measures, not an
        // upper bound of it.
        let mut ctx = ctx16();
        ctx.het = HetSpec { het: 0.3, straggler_prob: 0.05, straggler_mult: 4.0, seed: 13 };
        ctx.faults = Some(FaultSpec { prob: 0.02, mttr: 10 });
        let cand = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
        let v = validate(&cand, &ctx, "quickstart", CollectiveKind::Simulated).unwrap();
        let rel = v.makespan_delta_seconds.abs() / v.measured_makespan_seconds.max(1e-30);
        assert!(
            rel < 1e-9,
            "fault-armed makespan drift: modelled {} vs measured {}",
            v.modelled_makespan_seconds,
            v.measured_makespan_seconds
        );
        assert_eq!(v.modelled_comm_bytes, v.measured_comm_bytes);
        // The trace must actually have degraded some barriers, or the
        // parity above would be vacuous: replay the same seeded trace at
        // the measured horizon and count survivor-priced groups.
        let topo = cand.topology().unwrap();
        let sched = cand.schedule().unwrap();
        let msg = ctx.n_params * 4;
        let secs: Vec<f64> = (0..topo.n_levels())
            .map(|l| ctx.cost.allreduce_seconds(topo.size(l), msg, topo.link(l), ctx.strategy))
            .collect();
        let survivor = |level: usize, n_part: usize| {
            ctx.cost.allreduce_seconds(n_part, msg, topo.link(level), ctx.strategy)
        };
        let plan = FaultPlan::Sampled(ctx.faults.unwrap());
        let stats = sim::replay_timeline_stats_faults(
            &topo,
            &sched,
            v.total_steps,
            ctx.step_seconds,
            &secs,
            &ctx.het,
            &plan,
            &survivor,
        );
        assert!(stats.preemptions > 0, "fault regime drew no outages at this seed");
        assert!(
            stats.degraded_group_barriers > 0,
            "no barrier was survivor-priced — the parity check proves nothing"
        );
        assert_eq!(stats.makespan_seconds.to_bits(), v.modelled_makespan_seconds.to_bits());
    }

    #[test]
    fn compressed_variants_ride_next_to_dense_and_outrank_them() {
        let mut space = SweepSpace::new(16).unwrap();
        space.compress = vec![Compression::parse("topk:0.05").unwrap()];
        let ctx = ctx16();
        let cands = enumerate(&space, &ctx);
        let n_dense = cands.iter().filter(|c| c.compress.is_none()).count();
        assert_eq!(cands.len(), 2 * n_dense, "every dense entry needs a compressed twin");
        let comp = cands.iter().find(|c| !c.compress.is_none()).unwrap();
        assert!(comp.label().ends_with("-topk0.05"), "{}", comp.label());
        // The twin moves fewer bytes and takes less comm time, but pays
        // for its lossiness in the convergence bound: the score inflates
        // the gradient-variance term M by the spec's
        // `variance_inflation`, so the compressed bound is strictly
        // looser and the ranking weighs bytes saved against noise added
        // (instead of letting every compressed twin ride the dense bound
        // to an unearned win).
        let ranked = rank(&space, &ctx).unwrap();
        let find = |label: &str| {
            ranked
                .iter()
                .position(|r| r.candidate.label() == label)
                .unwrap_or_else(|| panic!("{label} not ranked"))
        };
        for r in &ranked {
            if r.candidate.compress.is_none() {
                continue;
            }
            let dense_label =
                r.candidate.label().trim_end_matches("-topk0.05").to_string();
            let d = &ranked[find(&dense_label)];
            assert!(r.score.comm_bytes < d.score.comm_bytes, "{}", r.candidate.label());
            assert!(r.score.comm_seconds < d.score.comm_seconds);
            assert!(
                r.score.bound > d.score.bound,
                "{} must pay a convergence penalty over its dense twin",
                r.candidate.label()
            );
            assert!(r.score.makespan_seconds < d.score.makespan_seconds);
        }
        // An empty compress list leaves the space bit-stable.
        let plain = SweepSpace::new(16).unwrap();
        let a = rank(&plain, &ctx).unwrap();
        space.compress.clear();
        let b = rank(&space, &ctx).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.candidate, y.candidate);
            assert_eq!(x.score.time_to_target.to_bits(), y.score.time_to_target.to_bits());
        }
        // Listing "none" is a contradiction, not a silent duplicate.
        let mut bad = SweepSpace::new(16).unwrap();
        bad.compress = vec![Compression::None];
        assert!(rank(&bad, &ctx).is_err());
    }

    #[test]
    fn compression_noise_penalty_orders_bounds() {
        // The Thm 3.4 penalty must order by information lost: coarser
        // quantization (q4 > q8) and smaller kept ratios (topk:R,
        // decreasing R) pay strictly more; error feedback halves the
        // exposure; keeping everything (topk:1) pays exactly nothing.
        let ctx = ctx16();
        let base = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
        let bound_of = |spec: Option<&str>| {
            let mut c = base.clone();
            if let Some(s) = spec {
                c.compress = Compression::parse(s).unwrap();
            }
            score(&c, &ctx).unwrap().bound
        };
        let dense = bound_of(None);
        assert!(bound_of(Some("q8")) > dense);
        assert!(bound_of(Some("q4")) > bound_of(Some("q8")), "q4 loses more than q8");
        assert!(bound_of(Some("q8:noef")) > bound_of(Some("q8")), "no error feedback costs more");
        let mut prev = f64::INFINITY;
        for r in ["0.01", "0.05", "0.25", "0.9"] {
            let b = bound_of(Some(&format!("topk:{r}")));
            assert!(b < prev, "topk penalty must decrease as R grows (R={r})");
            assert!(b > dense, "lossy topk:{r} must cost something");
            prev = b;
        }
        // topk:1 transmits every coordinate: bit-identical to the dense bound.
        assert_eq!(bound_of(Some("topk:1")).to_bits(), dense.to_bits());
    }

    #[test]
    fn compressed_validation_measures_the_compressed_account() {
        // The engine's reducer prices a compressed run with the same
        // payload_bytes the planner scores with: modelled-vs-measured
        // parity must hold for a compressed candidate, and the measured
        // bytes must sit below the candidate's own dense score.
        let ctx = ctx16();
        let mut cand = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
        cand.compress = Compression::parse("topk:0.05").unwrap();
        let v = validate(&cand, &ctx, "quickstart", CollectiveKind::Simulated).unwrap();
        assert_eq!(v.modelled_comm_bytes, v.measured_comm_bytes);
        let rel = v.delta_seconds.abs() / v.measured_comm_seconds.max(1e-30);
        assert!(rel < 1e-9, "compressed comm drift {rel}");
        let vctx = ScoreCtx { horizon: v.total_steps, ..ctx };
        let dense_at_measured = score(
            &Candidate { compress: Compression::None, ..cand.clone() },
            &vctx,
        )
        .unwrap();
        assert!(
            v.measured_comm_bytes < dense_at_measured.comm_bytes,
            "compressed run moved {} bytes vs dense {}",
            v.measured_comm_bytes,
            dense_at_measured.comm_bytes
        );
    }

    #[test]
    fn contradictory_space_is_rejected() {
        let ctx = ctx16();
        let mut space = SweepSpace::new(16).unwrap();
        space.min_levels = 4;
        space.max_levels = 3;
        assert!(rank(&space, &ctx).is_err());
        let mut space = SweepSpace::new(16).unwrap();
        space.k1_grid = vec![];
        assert!(rank(&space, &ctx).is_err());
        let mut space = SweepSpace::new(16).unwrap();
        space.k1_grid = vec![0, 2];
        assert!(rank(&space, &ctx).is_err());
    }

    #[test]
    fn validation_config_is_well_formed() {
        let cand = Candidate::with_default_links(vec![2, 4, 8], vec![2, 4, 8]).unwrap();
        let cfg = validation_config(&cand, "quickstart", CollectiveKind::Simulated).unwrap();
        assert_eq!(cfg.p, 8);
        assert_eq!(cfg.epochs, VALIDATION_EPOCHS);
        assert!(cfg.record_trace);
        cfg.validate().unwrap();
    }
}
