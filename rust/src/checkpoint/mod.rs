//! Parameter snapshots: save / load flat parameter vectors (plus a JSON
//! sidecar describing the layout) for warm starts, cross-run comparisons,
//! and exporting trained models.
//!
//! Format: `<path>` is a little-endian f32 blob identical to the AOT
//! `*.init.bin` convention; `<path>.json` records the layout, the model
//! name, and a checksum so mismatched loads fail loudly.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::params::{FlatParams, ParamLayout};
use crate::util::json::Json;

/// FNV-1a over the raw bytes — cheap integrity check.
fn checksum(params: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for v in params {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

pub fn save(path: &Path, model: &str, layout: &ParamLayout, params: &FlatParams) -> Result<()> {
    save_with_schedule(path, model, layout, params, None)
}

/// [`save`] plus the schedule-policy sidecar fields: the run's canonical
/// policy spec (`PolicyKind::spec`) and the controller's serializable
/// state (`SchedulePolicy::state`).  A warm start restores both, so a
/// resumed adaptive run continues its controller exactly; loading under a
/// different `--schedule` fails loudly in `driver::run`.
pub fn save_with_schedule(
    path: &Path,
    model: &str,
    layout: &ParamLayout,
    params: &FlatParams,
    schedule: Option<(&str, &Json)>,
) -> Result<()> {
    save_with_meta(path, model, layout, params, schedule, None, 0)
}

/// [`save_with_schedule`] plus the elastic-run sidecar fields: the
/// saving run's topology chain (`levels`, innermost first, last = P) and
/// its final membership epoch.  Both are resume guards: a warm start
/// under a different hierarchy, or of an elastic run without its fault
/// layer, fails loudly in `driver::run` instead of silently averaging
/// across a topology the saved parameters never saw.  `levels = None`
/// and `membership_epoch = 0` write a sidecar byte-identical to
/// [`save_with_schedule`]'s, so pre-fault readers stay compatible.
pub fn save_with_meta(
    path: &Path,
    model: &str,
    layout: &ParamLayout,
    params: &FlatParams,
    schedule: Option<(&str, &Json)>,
    levels: Option<&[usize]>,
    membership_epoch: u64,
) -> Result<()> {
    if params.len() != layout.total {
        bail!("params len {} != layout total {}", params.len(), layout.total);
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let bytes: Vec<u8> = params.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write(path, &bytes).with_context(|| format!("writing {}", path.display()))?;

    let mut tensors = Vec::new();
    for e in &layout.entries {
        let mut o = Json::obj();
        o.set("name", Json::from(e.name.as_str()))
            .set("shape", Json::Arr(e.shape.iter().map(|&d| Json::from(d)).collect()))
            .set("offset", Json::from(e.offset))
            .set("size", Json::from(e.size));
        tensors.push(o);
    }
    let mut meta = Json::obj();
    meta.set("model", Json::from(model))
        .set("n_params", Json::from(layout.total))
        .set("checksum", Json::from(format!("{:016x}", checksum(params))))
        .set("params", Json::Arr(tensors));
    if let Some((spec, state)) = schedule {
        let mut sch = Json::obj();
        sch.set("spec", Json::from(spec)).set("state", state.clone());
        meta.set("schedule_policy", sch);
    }
    if let Some(levels) = levels {
        meta.set("levels", Json::Arr(levels.iter().map(|&s| Json::from(s)).collect()));
    }
    if membership_epoch > 0 {
        meta.set("membership_epoch", Json::from(membership_epoch as usize));
    }
    std::fs::write(sidecar(path), meta.pretty())?;
    Ok(())
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub model: String,
    pub layout: ParamLayout,
    pub params: FlatParams,
    /// Schedule-policy spec + controller state, when the saving run
    /// recorded them (checkpoints from before the policy layer have
    /// none — loaders treat that as "no constraint").
    pub schedule_policy: Option<(String, Json)>,
    /// The saving run's topology chain (innermost first, last = P), when
    /// recorded.  Legacy sidecars have none — "no constraint".
    pub levels: Option<Vec<usize>>,
    /// The saving run's final membership epoch (None or 0 = the run was
    /// not elastic / saw no membership events).
    pub membership_epoch: Option<u64>,
}

pub fn load(path: &Path) -> Result<Snapshot> {
    let meta_text = std::fs::read_to_string(sidecar(path))
        .with_context(|| format!("reading sidecar {}", sidecar(path).display()))?;
    let meta = Json::parse(&meta_text)?;
    let layout = ParamLayout::from_json(meta.req("params")?)?;
    let model = meta.req("model")?.as_str()?.to_string();
    let params = crate::params::load_init_blob(path, &layout)?;
    let expect = meta.req("checksum")?.as_str()?.to_string();
    let got = format!("{:016x}", checksum(&params));
    if got != expect {
        bail!("checkpoint {} corrupt: checksum {got} != {expect}", path.display());
    }
    let schedule_policy = match meta.get("schedule_policy") {
        Some(sch) => {
            Some((sch.req("spec")?.as_str()?.to_string(), sch.req("state")?.clone()))
        }
        None => None,
    };
    let levels = match meta.get("levels") {
        Some(v) => Some(v.usize_arr()?),
        None => None,
    };
    let membership_epoch = match meta.get("membership_epoch") {
        Some(v) => Some(v.as_usize()? as u64),
        None => None,
    };
    Ok(Snapshot { model, layout, params, schedule_policy, levels, membership_epoch })
}

fn sidecar(path: &Path) -> std::path::PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".json");
    std::path::PathBuf::from(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::ParamEntry;

    fn layout() -> ParamLayout {
        ParamLayout::from_entries(vec![
            ParamEntry { name: "0/w".into(), shape: vec![2, 3], offset: 0, size: 6 },
            ParamEntry { name: "0/b".into(), shape: vec![3], offset: 6, size: 3 },
        ])
        .unwrap()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hier_avg_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let l = layout();
        let params: Vec<f32> = (0..9).map(|i| i as f32 * 0.25).collect();
        let p = tmp("a.bin");
        save(&p, "test-model", &l, &params).unwrap();
        let snap = load(&p).unwrap();
        assert_eq!(snap.model, "test-model");
        assert_eq!(snap.layout, l);
        assert_eq!(snap.params, params);
    }

    #[test]
    fn schedule_sidecar_roundtrips() {
        let l = layout();
        let params = vec![0.5f32; 9];
        let p = tmp("sched.bin");
        // Without schedule info the sidecar stays policy-free.
        save(&p, "m", &l, &params).unwrap();
        assert!(load(&p).unwrap().schedule_policy.is_none());
        // With it, spec and controller state come back verbatim.
        let state = Json::parse(r#"{"offset": 128, "intervals": [2, 16]}"#).unwrap();
        save_with_schedule(&p, "m", &l, &params, Some(("adaptive:0.25", &state))).unwrap();
        let snap = load(&p).unwrap();
        let (spec, got) = snap.schedule_policy.unwrap();
        assert_eq!(spec, "adaptive:0.25");
        assert_eq!(got, state);
    }

    #[test]
    fn elastic_meta_roundtrips_and_stays_legacy_compatible() {
        let l = layout();
        let params = vec![0.25f32; 9];
        let p = tmp("meta.bin");
        // Legacy save: no topology, no membership epoch — and the sidecar
        // bytes are identical to what save_with_schedule wrote before the
        // fault layer existed.
        save_with_schedule(&p, "m", &l, &params, None).unwrap();
        let legacy_sidecar = std::fs::read_to_string(sidecar(&p)).unwrap();
        let snap = load(&p).unwrap();
        assert!(snap.levels.is_none());
        assert!(snap.membership_epoch.is_none());
        save_with_meta(&p, "m", &l, &params, None, None, 0).unwrap();
        assert_eq!(std::fs::read_to_string(sidecar(&p)).unwrap(), legacy_sidecar);
        // Full metadata round-trips.
        let state = Json::parse(r#"{"offset": 64}"#).unwrap();
        save_with_meta(
            &p,
            "m",
            &l,
            &params,
            Some(("adaptive:0.25", &state)),
            Some(&[4, 16]),
            7,
        )
        .unwrap();
        let snap = load(&p).unwrap();
        assert_eq!(snap.levels.as_deref(), Some(&[4usize, 16][..]));
        assert_eq!(snap.membership_epoch, Some(7));
        assert_eq!(snap.schedule_policy.unwrap().0, "adaptive:0.25");
    }

    #[test]
    fn corrupt_blob_detected() {
        let l = layout();
        let params = vec![1.0f32; 9];
        let p = tmp("b.bin");
        save(&p, "m", &l, &params).unwrap();
        // Flip a byte.
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[5] ^= 0xff;
        std::fs::write(&p, bytes).unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("checksum"));
    }

    #[test]
    fn wrong_length_detected() {
        let l = layout();
        let p = tmp("c.bin");
        save(&p, "m", &l, &vec![0.5f32; 9]).unwrap();
        std::fs::write(&p, [0u8; 8]).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn save_rejects_mismatched_params() {
        let l = layout();
        assert!(save(&tmp("d.bin"), "m", &l, &vec![0.0; 5]).is_err());
    }
}
