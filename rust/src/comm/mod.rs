//! Simulated collectives over flat parameter buffers + the hierarchical
//! communication cost model.
//!
//! Three layers, independently pluggable:
//!
//! - [`collective`] — *how bytes move*: the [`Collective`] trait with a
//!   single-thread simulated engine, a spawn-per-call sharded engine, and
//!   a persistent-worker-pool pooled engine (reduce-scatter/all-gather
//!   over `exec::WorkerPool`).  All engines compute the identical
//!   arithmetic mean (summation order is fixed), so training dynamics are
//!   exact and engine choice is a pure throughput knob.
//! - [`compress`] — *what bytes move*: an optional payload transform
//!   (top-k / random-k sparsification, 8/4-bit linear quantization) with
//!   per-learner error-feedback residuals; `--compress none` builds no
//!   wrapper at all, keeping the dense path byte-for-byte legacy.
//! - [`reduce`] — *what a reduction does to the run*: in-place group
//!   averaging plus aggregate and per-hierarchy-level accounting.
//! - [`cost`] — *what a reduction costs*: an α–β model with distinct
//!   intra-node (NVLink-class), inter-node (Infiniband-class), and
//!   cross-rack (oversubscribed spine) links — the quantity the paper
//!   argues about but could not measure (§4.3: their PyTorch stack lacked
//!   GPU-direct).  Three allreduce schedules are modelled (naive
//!   gather+broadcast, binary tree, ring).

pub mod collective;
pub mod compress;
pub mod cost;
pub mod reduce;

pub use collective::{
    Collective, CollectiveKind, PooledCollective, ShardedCollective, SimulatedCollective,
};
pub use compress::{CompressedCollective, Compression, EfState};
pub use cost::{CommStats, CostModel, LevelStats, ReduceStrategy};
pub use reduce::Reducer;
