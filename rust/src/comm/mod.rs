//! Simulated collectives over flat parameter buffers + the hierarchical
//! communication cost model.
//!
//! The averaging *algebra* is executed for real (replicas' buffers are
//! reduced and synchronized exactly as CUDA-aware MPI would), so training
//! dynamics are exact.  The *time* of each reduction is charged to an α–β
//! model with distinct intra-node (NVLink-class) and inter-node
//! (Infiniband-class) links — this is the quantity the paper argues about
//! but could not measure (§4.3: their PyTorch stack lacked GPU-direct).
//!
//! Three allreduce schedules are modelled (naive gather+broadcast, binary
//! tree, ring); all compute the identical arithmetic mean (summation order
//! is fixed), only the charged time differs.

pub mod cost;
pub mod reduce;

pub use cost::{CommStats, CostModel, ReduceStrategy};
pub use reduce::Reducer;
