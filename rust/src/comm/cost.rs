//! α–β cost model for hierarchical reductions.
//!
//! A message of M bytes over a link costs `α + M·β` seconds.  Three link
//! tiers: intra-node and inter-node defaults are calibrated to the paper's
//! platform (IBM Minsky: NVLink ~40 GB/s intra node, EDR Infiniband
//! ~10 GB/s inter node, α ≈ 5 µs / 20 µs); the rack-fabric tier models an
//! oversubscribed cross-rack spine (~5 GB/s, α ≈ 50 µs) and is only
//! charged when a hierarchy level is explicitly assigned to it via the
//! config's per-level `links` override.

use crate::comm::compress::Compression;
use crate::topology::LinkClass;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Per-message latency on an intra-node link (seconds).
    pub alpha_intra: f64,
    /// Per-byte time on an intra-node link (seconds/byte).
    pub beta_intra: f64,
    /// Per-message latency on an inter-node link (seconds).
    pub alpha_inter: f64,
    /// Per-byte time on an inter-node link (seconds/byte).
    pub beta_inter: f64,
    /// Per-message latency on the cross-rack fabric (seconds).
    pub alpha_rack: f64,
    /// Per-byte time on the cross-rack fabric (seconds/byte).
    pub beta_rack: f64,
}

impl Default for CostModel {
    /// Provenance: these are *literature* constants for the paper's
    /// platform (IBM Minsky, Zhou & Cong 2019 §4 — NVLink ≈ 40 GB/s
    /// intra-node, EDR InfiniBand ≈ 10 GB/s inter-node, with typical
    /// small-message latencies of ~5 µs / ~20 µs; the rack tier is a
    /// conventional ~5 GB/s / ~50 µs oversubscribed spine), not
    /// measurements of this host.  To re-derive constants from *this*
    /// machine's measured reduction throughput, run the benchkit suite
    /// (`scripts/bless_bench.sh`) and then
    /// `scripts/calibrate_cost_model.py`, which reads BENCH_*.json and
    /// prints suggested α/β overrides (JSON config keys `alpha_intra` …
    /// `beta_rack`) plus a suggested `sim_step_seconds` device constant.
    fn default() -> Self {
        CostModel {
            alpha_intra: 5e-6,
            beta_intra: 1.0 / 40e9,
            alpha_inter: 20e-6,
            beta_inter: 1.0 / 10e9,
            alpha_rack: 50e-6,
            beta_rack: 1.0 / 5e9,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReduceStrategy {
    /// Gather everything to a root, then broadcast: 2(n−1) sequential
    /// messages of the full payload.
    Naive,
    /// Binomial tree reduce + broadcast: 2·ceil(log2 n) rounds.
    Tree,
    /// Ring allreduce: 2(n−1) rounds of M/n-sized chunks.
    #[default]
    Ring,
}

impl ReduceStrategy {
    pub fn parse(s: &str) -> Option<ReduceStrategy> {
        match s {
            "naive" => Some(ReduceStrategy::Naive),
            "tree" => Some(ReduceStrategy::Tree),
            "ring" => Some(ReduceStrategy::Ring),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReduceStrategy::Naive => "naive",
            ReduceStrategy::Tree => "tree",
            ReduceStrategy::Ring => "ring",
        }
    }
}

impl CostModel {
    fn link_params(&self, link: LinkClass) -> (f64, f64) {
        match link {
            LinkClass::IntraNode => (self.alpha_intra, self.beta_intra),
            LinkClass::InterNode => (self.alpha_inter, self.beta_inter),
            LinkClass::RackFabric => (self.alpha_rack, self.beta_rack),
        }
    }

    /// Modelled wall time of an allreduce over `n` participants exchanging
    /// `bytes` each, on links of class `link`.
    pub fn allreduce_seconds(
        &self,
        n: usize,
        bytes: usize,
        link: LinkClass,
        strategy: ReduceStrategy,
    ) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let (alpha, beta) = self.link_params(link);
        let m = bytes as f64;
        match strategy {
            ReduceStrategy::Naive => 2.0 * (n as f64 - 1.0) * (alpha + m * beta),
            ReduceStrategy::Tree => {
                let rounds = (n as f64).log2().ceil();
                2.0 * rounds * (alpha + m * beta)
            }
            ReduceStrategy::Ring => {
                let n_f = n as f64;
                2.0 * (n_f - 1.0) * alpha + 2.0 * ((n_f - 1.0) / n_f) * m * beta
            }
        }
    }

    /// [`CostModel::allreduce_seconds`] with the payload priced under a
    /// compression's wire format (see `comm::compress` for the per-spec
    /// byte math; the per-strategy round structure is unchanged — fewer
    /// bytes ride the same schedule).
    pub fn compressed_allreduce_seconds(
        &self,
        n: usize,
        n_params: usize,
        comp: Compression,
        link: LinkClass,
        strategy: ReduceStrategy,
    ) -> f64 {
        self.allreduce_seconds(n, comp.payload_bytes(n_params), link, strategy)
    }

    /// [`CostModel::allreduce_bytes`] under a compression's wire format.
    pub fn compressed_allreduce_bytes(
        &self,
        n: usize,
        n_params: usize,
        comp: Compression,
        strategy: ReduceStrategy,
    ) -> u64 {
        self.allreduce_bytes(n, comp.payload_bytes(n_params), strategy)
    }

    /// Bytes crossing the network for one allreduce (per participant,
    /// counting sends).
    pub fn allreduce_bytes(&self, n: usize, bytes: usize, strategy: ReduceStrategy) -> u64 {
        if n <= 1 {
            return 0;
        }
        let m = bytes as u64;
        match strategy {
            ReduceStrategy::Naive => 2 * (n as u64 - 1) * m,
            ReduceStrategy::Tree => 2 * (n as u64 - 1) * m,
            ReduceStrategy::Ring => {
                // each rank sends 2(n-1) chunks of m/n
                2 * (n as u64 - 1) * (m / n as u64) * n as u64
            }
        }
    }
}

/// Per-hierarchy-level communication account (one entry per
/// `HierTopology` level; seconds follow the concurrent-groups convention
/// of `Reducer::reduce_level` — the max over a level's symmetric groups).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LevelStats {
    pub reductions: u64,
    pub bytes: u64,
    pub seconds: f64,
}

/// Running communication account for one training run.  Local = the
/// intra-node tier, global = the inter-node tier, rack = the cross-rack
/// fabric tier (zero unless the config assigns a level to it).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    pub local_reductions: u64,
    pub global_reductions: u64,
    pub rack_reductions: u64,
    pub local_bytes: u64,
    pub global_bytes: u64,
    pub rack_bytes: u64,
    pub local_seconds: f64,
    pub global_seconds: f64,
    pub rack_seconds: f64,
}

impl CommStats {
    pub fn total_seconds(&self) -> f64 {
        self.local_seconds + self.global_seconds + self.rack_seconds
    }

    pub fn merge(&mut self, other: &CommStats) {
        self.local_reductions += other.local_reductions;
        self.global_reductions += other.global_reductions;
        self.rack_reductions += other.rack_reductions;
        self.local_bytes += other.local_bytes;
        self.global_bytes += other.global_bytes;
        self.rack_bytes += other.rack_bytes;
        self.local_seconds += other.local_seconds;
        self.global_seconds += other.global_seconds;
        self.rack_seconds += other.rack_seconds;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::LinkClass::*;

    #[test]
    fn single_participant_is_free() {
        let cm = CostModel::default();
        for s in [ReduceStrategy::Naive, ReduceStrategy::Tree, ReduceStrategy::Ring] {
            assert_eq!(cm.allreduce_seconds(1, 1 << 20, InterNode, s), 0.0);
        }
    }

    #[test]
    fn ring_beats_naive_for_large_payloads() {
        let cm = CostModel::default();
        let bytes = 400 << 20; // 100M params
        let naive = cm.allreduce_seconds(16, bytes, InterNode, ReduceStrategy::Naive);
        let ring = cm.allreduce_seconds(16, bytes, InterNode, ReduceStrategy::Ring);
        assert!(ring < naive / 8.0, "ring={ring} naive={naive}");
    }

    #[test]
    fn tree_beats_naive_latency() {
        let cm = CostModel::default();
        // tiny payload => latency dominated
        let naive = cm.allreduce_seconds(64, 4, InterNode, ReduceStrategy::Naive);
        let tree = cm.allreduce_seconds(64, 4, InterNode, ReduceStrategy::Tree);
        assert!(tree < naive);
    }

    #[test]
    fn intra_is_cheaper_than_inter() {
        let cm = CostModel::default();
        let bytes = 4 << 20;
        for s in [ReduceStrategy::Naive, ReduceStrategy::Tree, ReduceStrategy::Ring] {
            assert!(
                cm.allreduce_seconds(4, bytes, IntraNode, s)
                    < cm.allreduce_seconds(4, bytes, InterNode, s)
            );
        }
    }

    #[test]
    fn rack_is_the_slowest_tier() {
        let cm = CostModel::default();
        for &bytes in &[4usize, 4 << 20] {
            for s in [ReduceStrategy::Naive, ReduceStrategy::Tree, ReduceStrategy::Ring] {
                assert!(
                    cm.allreduce_seconds(4, bytes, InterNode, s)
                        < cm.allreduce_seconds(4, bytes, RackFabric, s),
                    "bytes={bytes} strategy={s:?}"
                );
            }
        }
    }

    #[test]
    fn cost_monotone_in_participants_and_bytes() {
        let cm = CostModel::default();
        for s in [ReduceStrategy::Naive, ReduceStrategy::Tree, ReduceStrategy::Ring] {
            assert!(
                cm.allreduce_seconds(8, 1 << 20, InterNode, s)
                    <= cm.allreduce_seconds(16, 1 << 20, InterNode, s)
            );
            assert!(
                cm.allreduce_seconds(8, 1 << 20, InterNode, s)
                    < cm.allreduce_seconds(8, 1 << 22, InterNode, s)
            );
        }
    }

    #[test]
    fn stats_merge() {
        let mut a = CommStats { local_reductions: 1, global_seconds: 0.5, ..Default::default() };
        let b = CommStats { local_reductions: 2, global_seconds: 1.0, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.local_reductions, 3);
        assert!((a.global_seconds - 1.5).abs() < 1e-12);
    }
}
