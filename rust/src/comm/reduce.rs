//! The reducer: group averaging over learner replicas with cost accounting
//! against the topology's link classes.
//!
//! This is the L3 hot path (profiled in benches/reduction.rs).  The
//! *arithmetic* is delegated to a pluggable [`Collective`] (simulated
//! single-thread, spawn-per-call sharded, or persistent-pool pooled); all
//! keep a fixed summation order (learner-index ascending), so results are
//! identical across collectives, reduce strategies, and runs.  The reducer
//! owns what the collective does not: the α–β cost model, the aggregate
//! [`CommStats`], and per-hierarchy-level [`LevelStats`].

use crate::comm::collective::{Collective, SimulatedCollective};
use crate::comm::compress::Compression;
use crate::comm::cost::{CommStats, CostModel, LevelStats, ReduceStrategy};
use crate::params::{FlatParams, Rows, RowsMut};
use crate::topology::{HierTopology, LinkClass, Topology};
use crate::util::simd;

pub struct Reducer {
    pub cost: CostModel,
    pub strategy: ReduceStrategy,
    pub stats: CommStats,
    /// Payload compression used for *pricing* full-group barriers.  The
    /// matching value transform lives in the collective (a
    /// `CompressedCollective` wrapper installed by the engine); the
    /// reducer only needs the wire format for the α–β model.  `None`
    /// prices the exact legacy `4·n_params` payload.
    pub compression: Compression,
    /// What the same reduction events would have moved densely — the
    /// savings denominator for the run record's compression block.
    /// Equals the charged totals when `compression` is `None`.
    pub dense_bytes: u64,
    collective: Box<dyn Collective>,
    scratch: Vec<f32>,
    level_stats: Vec<LevelStats>,
}

impl Reducer {
    /// A reducer on the default (simulated, single-thread) collective.
    pub fn new(cost: CostModel, strategy: ReduceStrategy, n_params: usize) -> Reducer {
        Reducer::with_collective(cost, strategy, n_params, Box::new(SimulatedCollective))
    }

    pub fn with_collective(
        cost: CostModel,
        strategy: ReduceStrategy,
        n_params: usize,
        collective: Box<dyn Collective>,
    ) -> Reducer {
        Reducer {
            cost,
            strategy,
            stats: CommStats::default(),
            compression: Compression::None,
            dense_bytes: 0,
            collective,
            scratch: vec![0.0; n_params],
            level_stats: Vec::new(),
        }
    }

    pub fn collective_name(&self) -> &'static str {
        self.collective.name()
    }

    /// Pre-size the per-level accounts (one per hierarchy level) so the
    /// vector has a stable length even for levels that never fire.
    pub fn reserve_levels(&mut self, n_levels: usize) {
        if self.level_stats.len() < n_levels {
            self.level_stats.resize(n_levels, LevelStats::default());
        }
    }

    /// Per-hierarchy-level accounts (filled by [`Reducer::reduce_level`]).
    pub fn level_stats(&self) -> &[LevelStats] {
        &self.level_stats
    }

    /// Execute one group reduction (data movement + modelled cost), without
    /// touching any statistics.
    fn group_once(
        &mut self,
        replicas: RowsMut<'_>,
        group: std::ops::Range<usize>,
        link: LinkClass,
    ) -> (f64, u64) {
        let n = group.len();
        debug_assert!(n >= 1);
        // Priced under the compression's wire format; with `None` this is
        // the exact legacy `4·n_params` integer, so seconds/bytes are
        // bit-identical to every pre-compression golden.
        let bytes = self.compression.payload_bytes(self.scratch.len());
        self.collective.average_group(replicas, group, &mut self.scratch);
        let secs = self.cost.allreduce_seconds(n, bytes, link, self.strategy);
        let moved = self.cost.allreduce_bytes(n, bytes, self.strategy);
        self.dense_bytes += self.cost.allreduce_bytes(n, self.scratch.len() * 4, self.strategy);
        (secs, moved)
    }

    /// One group reduction charged to the aggregate stats.
    fn charged_group(
        &mut self,
        replicas: RowsMut<'_>,
        group: std::ops::Range<usize>,
        link: LinkClass,
    ) -> (f64, u64) {
        let (secs, moved) = self.group_once(replicas, group, link);
        self.charge_to_link(link, secs, moved);
        (secs, moved)
    }

    /// Average the replica rows in `group` and write the mean back into
    /// every member.  Returns the modelled seconds.
    pub fn average_group(
        &mut self,
        replicas: RowsMut<'_>,
        group: std::ops::Range<usize>,
        link: LinkClass,
    ) -> f64 {
        self.charged_group(replicas, group, link).0
    }

    /// Reduce every group at `level` of the hierarchy.  Groups at one level
    /// are symmetric and reduce concurrently in the modelled time (max over
    /// groups = any one group), so only one group's time is charged, but
    /// every group's event/bytes are counted.
    ///
    /// Size-1 levels below the top are no-ops (the legacy `local_average`
    /// S=1 behaviour); the outermost level always counts its event, even
    /// for the degenerate P=1 run (legacy `global_average` behaviour).
    pub fn reduce_level(
        &mut self,
        mut replicas: RowsMut<'_>,
        topo: &HierTopology,
        level: usize,
    ) -> f64 {
        let size = topo.size(level);
        if size <= 1 && level + 1 < topo.n_levels() {
            return 0.0;
        }
        let link = topo.link(level);
        let mut max_secs: f64 = 0.0;
        let mut total_secs: f64 = 0.0;
        let mut reductions = 0u64;
        let mut bytes = 0u64;
        for g in 0..topo.n_groups(level) {
            let (secs, moved) =
                self.charged_group(replicas.reborrow(), topo.group_members(level, g), link);
            max_secs = max_secs.max(secs);
            total_secs += secs;
            reductions += 1;
            bytes += moved;
        }
        // Groups are concurrent: subtract the serialized surplus.
        let surplus = total_secs - max_secs;
        match link {
            LinkClass::IntraNode => self.stats.local_seconds -= surplus,
            LinkClass::InterNode => self.stats.global_seconds -= surplus,
            LinkClass::RackFabric => self.stats.rack_seconds -= surplus,
        }
        self.reserve_levels(level + 1);
        let ls = &mut self.level_stats[level];
        ls.reductions += reductions;
        ls.bytes += bytes;
        ls.seconds += max_secs;
        max_secs
    }

    /// Charge one reduction's seconds/bytes to `link`'s aggregate account.
    fn charge_to_link(&mut self, link: LinkClass, secs: f64, moved: u64) {
        match link {
            LinkClass::IntraNode => {
                self.stats.local_reductions += 1;
                self.stats.local_bytes += moved;
                self.stats.local_seconds += secs;
            }
            LinkClass::InterNode => {
                self.stats.global_reductions += 1;
                self.stats.global_bytes += moved;
                self.stats.global_seconds += secs;
            }
            LinkClass::RackFabric => {
                self.stats.rack_reductions += 1;
                self.stats.rack_bytes += moved;
                self.stats.rack_seconds += secs;
            }
        }
    }

    /// A degraded group's survivor mean: serial learner-index-ascending
    /// sum over the participating members, written back to participants
    /// only.  Deliberately *not* delegated to the collective — the serial
    /// sum is deterministic and identical across all collectives by
    /// construction, which keeps the fault layer's parameter math a
    /// single documented rule rather than three.  Priced and charged as
    /// an `n_part`-way allreduce on `link`.  Compression is deliberately
    /// *not* applied here: a degraded barrier is a rare recovery event
    /// and transmits dense, which keeps the elastic math and its pricing
    /// a single rule (the error-feedback references re-sync at the next
    /// full barrier regardless).
    fn survivor_group(
        &mut self,
        mut replicas: RowsMut<'_>,
        members: std::ops::Range<usize>,
        n_part: usize,
        part: &[bool],
        link: LinkClass,
    ) -> (f64, u64) {
        debug_assert!(n_part >= 1);
        let n = self.scratch.len();
        let bytes = n * 4;
        for x in self.scratch.iter_mut() {
            *x = 0.0;
        }
        // One vectorized pass per survivor, member index still ascending
        // and one source per pass — the exact scalar op sequence the
        // degraded-group test pins operation for operation.
        for j in members.clone() {
            if part[j] {
                simd::add_assign(&mut self.scratch[..n], &replicas.row(j)[..n]);
            }
        }
        let inv = 1.0 / n_part as f32;
        simd::scale_assign(&mut self.scratch, inv);
        for j in members {
            if part[j] {
                replicas.row_mut(j)[..n].copy_from_slice(&self.scratch);
            }
        }
        let secs = self.cost.allreduce_seconds(n_part, bytes, link, self.strategy);
        let moved = self.cost.allreduce_bytes(n_part, bytes, self.strategy);
        self.dense_bytes += moved;
        self.charge_to_link(link, secs, moved);
        (secs, moved)
    }

    /// [`Reducer::reduce_level`] over each group's *participants* only
    /// (`part[j]` false = preempted or migrated-out learner): the
    /// elastic-membership barrier.  A full group takes the exact legacy
    /// path — same collective call, same stats — so an armed fault layer
    /// with an empty trace reduces bit-identically to `reduce_level`.  A
    /// degraded group fires over its survivors with reweighted averaging
    /// (each survivor weighted `1/|survivors|`, absentees' frozen
    /// parameters untouched) via [`Reducer::survivor_group`], and a group
    /// with no participants fires no barrier at all.
    ///
    /// Returns `(max_secs, degraded_groups)`: the charged level time
    /// (same concurrent-groups convention as `reduce_level`) and how many
    /// groups fired over a strict subset of their members.
    pub fn reduce_level_survivors(
        &mut self,
        mut replicas: RowsMut<'_>,
        topo: &HierTopology,
        level: usize,
        part: &[bool],
    ) -> (f64, u64) {
        debug_assert_eq!(part.len(), topo.p());
        let size = topo.size(level);
        if size <= 1 && level + 1 < topo.n_levels() {
            return (0.0, 0);
        }
        let link = topo.link(level);
        let mut max_secs: f64 = 0.0;
        let mut total_secs: f64 = 0.0;
        let mut reductions = 0u64;
        let mut bytes = 0u64;
        let mut degraded = 0u64;
        for g in 0..topo.n_groups(level) {
            let members = topo.group_members(level, g);
            let n_part = members.clone().filter(|&j| part[j]).count();
            if n_part == 0 {
                continue; // whole group down: no barrier fires
            }
            let (secs, moved) = if n_part == members.len() {
                self.charged_group(replicas.reborrow(), members, link)
            } else {
                degraded += 1;
                self.survivor_group(replicas.reborrow(), members, n_part, part, link)
            };
            max_secs = max_secs.max(secs);
            total_secs += secs;
            reductions += 1;
            bytes += moved;
        }
        // Groups are concurrent: subtract the serialized surplus.
        let surplus = total_secs - max_secs;
        match link {
            LinkClass::IntraNode => self.stats.local_seconds -= surplus,
            LinkClass::InterNode => self.stats.global_seconds -= surplus,
            LinkClass::RackFabric => self.stats.rack_seconds -= surplus,
        }
        self.reserve_levels(level + 1);
        let ls = &mut self.level_stats[level];
        ls.reductions += reductions;
        ls.bytes += bytes;
        ls.seconds += max_secs;
        (max_secs, degraded)
    }

    /// Local averaging step: average within every cluster of the two-level
    /// topology (level 0 of the hierarchy).
    pub fn local_average(&mut self, replicas: RowsMut<'_>, topo: &Topology) -> f64 {
        self.reduce_level(replicas, &topo.to_hier(), 0)
    }

    /// Global averaging: one allreduce over all P learners (inter-node
    /// fabric; the outermost hierarchy level).
    pub fn global_average(&mut self, replicas: RowsMut<'_>, topo: &Topology) -> f64 {
        self.reduce_level(replicas, &topo.to_hier(), 1)
    }

    /// Compute the mean across ALL replica rows into `out` without touching
    /// the rows (used to evaluate the paper's w̃ mid-interval).
    pub fn mean_of(&self, replicas: Rows<'_>, out: &mut FlatParams) {
        out.resize(self.scratch.len(), 0.0);
        self.collective.mean_of(replicas, 0..replicas.rows(), out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::collective::ShardedCollective;
    use crate::params::ParamArena;

    fn replicas(p: usize, n: usize) -> ParamArena {
        let rows: Vec<Vec<f32>> =
            (0..p).map(|j| (0..n).map(|i| (j * n + i) as f32).collect()).collect();
        ParamArena::from_rows(&rows)
    }

    #[test]
    fn group_mean_exact() {
        let mut r = replicas(4, 8);
        let expect: Vec<f32> =
            (0..8).map(|i| (0..4).map(|j| (j * 8 + i) as f32).sum::<f32>() / 4.0).collect();
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 8);
        let topo = Topology::new(4, 4).unwrap();
        red.global_average(r.view_mut(), &topo);
        for j in 0..4 {
            assert_eq!(r.row(j), &expect[..]);
        }
        assert_eq!(red.stats.global_reductions, 1);
        assert!(red.stats.global_seconds > 0.0);
    }

    #[test]
    fn local_average_only_touches_clusters() {
        let mut r = replicas(4, 4);
        let topo = Topology::new(4, 2).unwrap();
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Tree, 4);
        red.local_average(r.view_mut(), &topo);
        assert_eq!(r.row(0), r.row(1));
        assert_eq!(r.row(2), r.row(3));
        assert_ne!(r.row(0), r.row(2));
        assert_eq!(red.stats.local_reductions, 2);
    }

    #[test]
    fn s1_local_average_is_noop() {
        let mut r = replicas(3, 4);
        let before = r.clone();
        let topo = Topology::new(3, 1).unwrap();
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 4);
        let secs = red.local_average(r.view_mut(), &topo);
        assert_eq!(secs, 0.0);
        assert_eq!(r, before);
        assert_eq!(red.stats.local_reductions, 0);
    }

    #[test]
    fn strategies_agree_numerically() {
        let topo = Topology::new(8, 4).unwrap();
        let mut outs = Vec::new();
        for s in [ReduceStrategy::Naive, ReduceStrategy::Tree, ReduceStrategy::Ring] {
            let mut r = replicas(8, 16);
            let mut red = Reducer::new(CostModel::default(), s, 16);
            red.local_average(r.view_mut(), &topo);
            red.global_average(r.view_mut(), &topo);
            outs.push(r);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
    }

    #[test]
    fn collectives_agree_bitwise() {
        let topo = Topology::new(8, 4).unwrap();
        let mut a = replicas(8, 4099); // not a multiple of the shard size
        let mut b = a.clone();
        let mut sim = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 4099);
        let mut sh = Reducer::with_collective(
            CostModel::default(),
            ReduceStrategy::Ring,
            4099,
            Box::new(ShardedCollective::new(3)),
        );
        sim.local_average(a.view_mut(), &topo);
        sim.global_average(a.view_mut(), &topo);
        sh.local_average(b.view_mut(), &topo);
        sh.global_average(b.view_mut(), &topo);
        assert_eq!(a, b);
        assert_eq!(sim.stats, sh.stats);
        assert_eq!(sim.level_stats(), sh.level_stats());
        assert_eq!(sh.collective_name(), "sharded");
    }

    #[test]
    fn mean_of_does_not_mutate() {
        let r = replicas(3, 4);
        let before = r.clone();
        let red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 4);
        let mut out = Vec::new();
        red.mean_of(r.view(), &mut out);
        assert_eq!(r, before);
        assert_eq!(out[0], (0.0 + 4.0 + 8.0) / 3.0);
    }

    #[test]
    fn concurrent_cluster_time_charged_once() {
        let topo = Topology::new(8, 4).unwrap();
        let mut r = replicas(8, 1024);
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 1024);
        let secs = red.local_average(r.view_mut(), &topo);
        // Two symmetric clusters run concurrently: charged time equals one
        // cluster's allreduce, not two.
        assert!((red.stats.local_seconds - secs).abs() < 1e-12);
    }

    #[test]
    fn rack_tier_charged_to_its_own_account() {
        use crate::topology::HierTopology;
        let topo = HierTopology::with_links(
            vec![2, 4, 8],
            vec![LinkClass::IntraNode, LinkClass::InterNode, LinkClass::RackFabric],
        )
        .unwrap();
        let mut r = replicas(8, 64);
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 64);
        red.reserve_levels(topo.n_levels());
        red.reduce_level(r.view_mut(), &topo, 0);
        red.reduce_level(r.view_mut(), &topo, 1);
        red.reduce_level(r.view_mut(), &topo, 2);
        assert_eq!(red.stats.local_reductions, 4);
        assert_eq!(red.stats.global_reductions, 2);
        assert_eq!(red.stats.rack_reductions, 1);
        assert!(red.stats.rack_seconds > 0.0);
        assert!(red.stats.rack_bytes > 0);
        // The rack fabric is the slowest tier: one 8-way reduction there
        // costs more than one 4-way on the inter-node tier.
        assert!(red.stats.rack_seconds > red.stats.global_seconds / 2.0);
        let total: f64 = red.level_stats().iter().map(|l| l.seconds).sum();
        assert!((red.stats.total_seconds() - total).abs() < 1e-12);
    }

    #[test]
    fn three_level_reduce_counts_per_level() {
        let topo = HierTopology::new(vec![2, 4, 8]).unwrap();
        let mut r = replicas(8, 16);
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 16);
        red.reserve_levels(topo.n_levels());
        red.reduce_level(r.view_mut(), &topo, 0); // 4 groups of 2, intra
        red.reduce_level(r.view_mut(), &topo, 1); // 2 groups of 4, inter
        red.reduce_level(r.view_mut(), &topo, 2); // 1 group of 8, inter
        let ls = red.level_stats();
        assert_eq!(ls.len(), 3);
        assert_eq!(ls[0].reductions, 4);
        assert_eq!(ls[1].reductions, 2);
        assert_eq!(ls[2].reductions, 1);
        assert_eq!(red.stats.local_reductions, 4);
        assert_eq!(red.stats.global_reductions, 3);
        // after the top-level reduction all replicas agree
        for j in 1..8 {
            assert_eq!(r.row(0), r.row(j));
        }
        // concurrent-group convention: aggregate seconds equal the per-level maxima
        let total: f64 = ls.iter().map(|l| l.seconds).sum();
        assert!((red.stats.total_seconds() - total).abs() < 1e-12);
    }

    #[test]
    fn survivor_reduction_with_full_groups_matches_legacy_bitwise() {
        use crate::topology::HierTopology;
        let topo = HierTopology::new(vec![2, 4, 8]).unwrap();
        let mut a = replicas(8, 16);
        let mut b = a.clone();
        let mut ra = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 16);
        let mut rb = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 16);
        let all = vec![true; 8];
        for level in 0..3 {
            let legacy = ra.reduce_level(a.view_mut(), &topo, level);
            let (surv, degraded) = rb.reduce_level_survivors(b.view_mut(), &topo, level, &all);
            assert_eq!(legacy.to_bits(), surv.to_bits());
            assert_eq!(degraded, 0);
        }
        assert_eq!(a, b);
        assert_eq!(ra.stats, rb.stats);
        assert_eq!(ra.level_stats(), rb.level_stats());
    }

    #[test]
    fn degraded_group_averages_survivors_and_freezes_absentees() {
        use crate::topology::HierTopology;
        let topo = HierTopology::new(vec![4, 8]).unwrap();
        let mut r = replicas(8, 4);
        let before = r.clone();
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 4);
        let mut part = vec![true; 8];
        part[1] = false; // group {0..4} degrades to {0,2,3}
        part[4] = false;
        part[5] = false; // group {4..8} degrades to {6,7}
        let (secs, degraded) = red.reduce_level_survivors(r.view_mut(), &topo, 0, &part);
        assert!(secs > 0.0);
        assert_eq!(degraded, 2);
        // Survivor mean: serial index-ascending sum times 1/|survivors| —
        // the documented reweighted-averaging rule, reproduced here
        // operation for operation.
        let inv3 = 1.0f32 / 3.0;
        let expect0: Vec<f32> = (0..4)
            .map(|i| (before.row(0)[i] + before.row(2)[i] + before.row(3)[i]) * inv3)
            .collect();
        for j in [0, 2, 3] {
            assert_eq!(r.row(j), &expect0[..], "survivor {j}");
        }
        assert_eq!(r.row(1), before.row(1), "absentee keeps frozen parameters");
        let inv2 = 1.0f32 / 2.0;
        let expect1: Vec<f32> =
            (0..4).map(|i| (before.row(6)[i] + before.row(7)[i]) * inv2).collect();
        for j in [6, 7] {
            assert_eq!(r.row(j), &expect1[..], "survivor {j}");
        }
        assert_eq!(r.row(4), before.row(4));
        assert_eq!(r.row(5), before.row(5));
        // priced as 3-way and 2-way allreduces on the intra-node tier
        assert_eq!(red.stats.local_reductions, 2);
    }

    #[test]
    fn all_down_group_fires_no_barrier() {
        use crate::topology::HierTopology;
        let topo = HierTopology::new(vec![4, 8]).unwrap();
        let mut r = replicas(8, 4);
        let before = r.clone();
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 4);
        let mut part = vec![true; 8];
        for p in part.iter_mut().take(4) {
            *p = false;
        }
        let (_, degraded) = red.reduce_level_survivors(r.view_mut(), &topo, 0, &part);
        assert_eq!(degraded, 0, "the surviving group is full, not degraded");
        for j in 0..4 {
            assert_eq!(r.row(j), before.row(j), "dead group left untouched");
        }
        assert_eq!(red.stats.local_reductions, 1);
    }
}
