//! The reducer: in-place group averaging over learner replicas, with cost
//! accounting against the topology's link classes.
//!
//! This is the L3 hot path (profiled in benches/reduction.rs).  The mean is
//! accumulated into a reusable scratch buffer with a fixed summation order
//! (learner-index ascending), so results are identical across reduce
//! strategies and across runs.

use crate::comm::cost::{CommStats, CostModel, ReduceStrategy};
use crate::params::FlatParams;
use crate::topology::{LinkClass, Topology};

pub struct Reducer {
    pub cost: CostModel,
    pub strategy: ReduceStrategy,
    pub stats: CommStats,
    scratch: Vec<f32>,
}

impl Reducer {
    pub fn new(cost: CostModel, strategy: ReduceStrategy, n_params: usize) -> Reducer {
        Reducer { cost, strategy, stats: CommStats::default(), scratch: vec![0.0; n_params] }
    }

    /// Average the replicas in `group` (indices into `replicas`) and write
    /// the mean back into every member.  Returns the modelled seconds.
    pub fn average_group(
        &mut self,
        replicas: &mut [FlatParams],
        group: std::ops::Range<usize>,
        link: LinkClass,
    ) -> f64 {
        let n = group.len();
        debug_assert!(n >= 1);
        let bytes = self.scratch.len() * 4;
        mean_into(&mut self.scratch, replicas, group.clone());
        // Broadcast the mean back to every member.  §Perf note: a threaded
        // fan-out was tried here and reverted — this image exposes a single
        // hardware thread, so the copies are already at memcpy speed.
        for j in group.clone() {
            replicas[j].copy_from_slice(&self.scratch);
        }
        let secs = self.cost.allreduce_seconds(n, bytes, link, self.strategy);
        let moved = self.cost.allreduce_bytes(n, bytes, self.strategy);
        match link {
            LinkClass::IntraNode => {
                self.stats.local_reductions += 1;
                self.stats.local_bytes += moved;
                self.stats.local_seconds += secs;
            }
            LinkClass::InterNode => {
                self.stats.global_reductions += 1;
                self.stats.global_bytes += moved;
                self.stats.global_seconds += secs;
            }
        }
        secs
    }

    /// Local averaging step: average within every cluster of the topology.
    /// All clusters reduce concurrently in the modelled time (max over
    /// clusters = any one cluster, since they are symmetric), so only one
    /// cluster's time is charged, but every cluster's event/bytes are
    /// counted.
    pub fn local_average(&mut self, replicas: &mut [FlatParams], topo: &Topology) -> f64 {
        if topo.s <= 1 {
            return 0.0;
        }
        let mut max_secs: f64 = 0.0;
        let mut total_secs: f64 = 0.0;
        for c in 0..topo.n_clusters() {
            let secs =
                self.average_group(replicas, topo.cluster_members(c), LinkClass::IntraNode);
            max_secs = max_secs.max(secs);
            total_secs += secs;
        }
        // Clusters are concurrent: subtract the serialized surplus.
        self.stats.local_seconds -= total_secs - max_secs;
        max_secs
    }

    /// Global averaging: one allreduce over all P learners (inter-node
    /// fabric).
    pub fn global_average(&mut self, replicas: &mut [FlatParams], topo: &Topology) -> f64 {
        self.average_group(replicas, 0..topo.p, LinkClass::InterNode)
    }

    /// Compute the mean across ALL replicas into `out` without touching the
    /// replicas (used to evaluate the paper's w̃ mid-interval).
    pub fn mean_of(&self, replicas: &[FlatParams], out: &mut FlatParams) {
        out.resize(self.scratch.len(), 0.0);
        mean_into(out, replicas, 0..replicas.len());
    }
}

/// Cache-block size for the accumulation loop (floats; 16 KiB fits L1 with
/// room for two source streams).  §Perf: the naive formulation makes S
/// full passes over `out` (S+1 streams of DRAM traffic); blocking keeps the
/// accumulator chunk resident so `out` is written once, which measured
/// 1.6-2.3x faster at 3.4M params (see EXPERIMENTS.md §Perf).
const MEAN_BLOCK: usize = 4096;

/// `out = mean(replicas[group])` with fixed (index-ascending) summation
/// order.  Hot loop: blocked accumulation, auto-vectorized inner loops.
fn mean_into(out: &mut [f32], replicas: &[FlatParams], group: std::ops::Range<usize>) {
    let n = group.len();
    let first = group.start;
    if n == 1 {
        out.copy_from_slice(&replicas[first]);
        return;
    }
    let inv = 1.0 / n as f32;
    let len = out.len();
    let mut start = 0usize;
    while start < len {
        let end = (start + MEAN_BLOCK).min(len);
        let blk = &mut out[start..end];
        blk.copy_from_slice(&replicas[first][start..end]);
        let mut rest = first + 1..group.end;
        // Pairs of sources per pass: halves the accumulator re-reads.
        while rest.len() >= 2 {
            let a = rest.next().unwrap();
            let b = rest.next().unwrap();
            let (sa, sb) = (&replicas[a][start..end], &replicas[b][start..end]);
            for ((o, x), y) in blk.iter_mut().zip(sa).zip(sb) {
                *o += *x + *y;
            }
        }
        if let Some(a) = rest.next() {
            for (o, x) in blk.iter_mut().zip(&replicas[a][start..end]) {
                *o += *x;
            }
        }
        for o in blk.iter_mut() {
            *o *= inv;
        }
        start = end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn replicas(p: usize, n: usize) -> Vec<FlatParams> {
        (0..p).map(|j| (0..n).map(|i| (j * n + i) as f32).collect()).collect()
    }

    #[test]
    fn group_mean_exact() {
        let mut r = replicas(4, 8);
        let expect: Vec<f32> =
            (0..8).map(|i| (0..4).map(|j| (j * 8 + i) as f32).sum::<f32>() / 4.0).collect();
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 8);
        let topo = Topology::new(4, 4).unwrap();
        red.global_average(&mut r, &topo);
        for j in 0..4 {
            assert_eq!(r[j], expect);
        }
        assert_eq!(red.stats.global_reductions, 1);
        assert!(red.stats.global_seconds > 0.0);
    }

    #[test]
    fn local_average_only_touches_clusters() {
        let mut r = replicas(4, 4);
        let topo = Topology::new(4, 2).unwrap();
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Tree, 4);
        red.local_average(&mut r, &topo);
        assert_eq!(r[0], r[1]);
        assert_eq!(r[2], r[3]);
        assert_ne!(r[0], r[2]);
        assert_eq!(red.stats.local_reductions, 2);
    }

    #[test]
    fn s1_local_average_is_noop() {
        let mut r = replicas(3, 4);
        let before = r.clone();
        let topo = Topology::new(3, 1).unwrap();
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 4);
        let secs = red.local_average(&mut r, &topo);
        assert_eq!(secs, 0.0);
        assert_eq!(r, before);
        assert_eq!(red.stats.local_reductions, 0);
    }

    #[test]
    fn strategies_agree_numerically() {
        let topo = Topology::new(8, 4).unwrap();
        let mut outs = Vec::new();
        for s in [ReduceStrategy::Naive, ReduceStrategy::Tree, ReduceStrategy::Ring] {
            let mut r = replicas(8, 16);
            let mut red = Reducer::new(CostModel::default(), s, 16);
            red.local_average(&mut r, &topo);
            red.global_average(&mut r, &topo);
            outs.push(r);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
    }

    #[test]
    fn mean_of_does_not_mutate() {
        let r = replicas(3, 4);
        let before = r.clone();
        let red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 4);
        let mut out = Vec::new();
        red.mean_of(&r, &mut out);
        assert_eq!(r, before);
        assert_eq!(out[0], (0.0 + 4.0 + 8.0) / 3.0);
    }

    #[test]
    fn concurrent_cluster_time_charged_once() {
        let topo = Topology::new(8, 4).unwrap();
        let mut r = replicas(8, 1024);
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 1024);
        let secs = red.local_average(&mut r, &topo);
        // Two symmetric clusters run concurrently: charged time equals one
        // cluster's allreduce, not two.
        assert!((red.stats.local_seconds - secs).abs() < 1e-12);
    }
}
