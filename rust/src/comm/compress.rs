//! Payload compression for the collective stack: sparsification (top-k /
//! random-k) and low-bit linear quantization (q8 / q4), each with a
//! per-learner error-feedback residual accumulator.
//!
//! The paper trades global for local reduction to cut *how often* learners
//! communicate; this layer is the orthogonal axis — *how much* each barrier
//! moves.  The design follows the error-feedback sparsified-SGD line
//! (Stich, Cordonnier & Jaggi, 2018): what a learner fails to transmit is
//! kept in a local residual and re-offered at the next barrier, so nothing
//! is ever silently dropped.
//!
//! ## What is compressed
//!
//! Collectives here average *parameters*, not gradients, so compressing the
//! raw vectors would destroy them (a top-5% mask zeroes 95% of the model).
//! Instead each learner transmits its **delta from a reference point**: the
//! parameter value it held right after its last compressed barrier.  For
//! learner `j` with reference `ref_j` and residual `e_j`:
//!
//! ```text
//! acc_j = (x_j − ref_j) + e_j          // accumulated untransmitted update
//! t_j   = C(acc_j)                     // compressed payload (what is sent)
//! e_j'  = acc_j − t_j                  // error feedback, kept locally
//! mean  = mean_j(ref_j) + mean_j(t_j)  // new group value
//! x_j, ref_j ← mean  for every member
//! ```
//!
//! `mean_j(ref_j)` is *not* transmitted: every member tracks its peers'
//! references locally (they are deterministic — each barrier leaves all
//! members on the same value), the same bookkeeping CHOCO-SGD style
//! gossip methods use.  Only `t_j` crosses the wire and only `t_j` is
//! priced.  With `C = identity` the barrier is an exact mean; with lossy
//! `C` the residual `e_j'` carries the shortfall forward.
//!
//! ## Wire format (what `payload_bytes` prices)
//!
//! Sparse payloads use an index-exchange format modeled on a sparse
//! reduce-scatter: a 4-byte count header plus `(u32 index, f32 value)`
//! pairs for the k selected coordinates.  Shard ownership ("skip
//! self-owned rows") is already captured by the per-strategy allreduce
//! byte formulas in [`CostModel`](crate::comm::cost::CostModel) — e.g. the
//! ring's `(n−1)/n` factor — so the payload here is the full k-pair
//! message and the strategy scales it.  Quantized payloads are a scale +
//! count header plus 1 byte (q8) or a half byte (q4) per coordinate.
//! Every encoding is capped at the dense size: a compressed barrier never
//! prices more than `4·n_params` bytes per message.
//!
//! ## Determinism contract
//!
//! Top-k selects by magnitude with ties broken toward the lower index —
//! no RNG, bit-stable across collectives and thread counts.  Random-k
//! draws from a dedicated `Pcg32` stream seeded by `(run seed, learner,
//! per-learner round counter)`, so selection depends only on the run
//! config and how many barriers the learner has participated in — never
//! on group iteration order or the engine's thread count.  Quantization
//! is pure per-coordinate arithmetic.  The wrapper serializes barrier
//! math behind a mutex; the wrapped engine still moves the dense mean of
//! references however it likes, so `--collective` stays a pure
//! throughput knob.
//!
//! With `--compress none` no wrapper is constructed at all: the dense
//! path is the exact legacy code, bit-identical to every existing golden.

use std::ops::Range;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::comm::collective::Collective;
use crate::params::{FlatParams, Rows, RowsMut};
use crate::util::rng::Pcg32;
use crate::util::simd;

/// Dedicated RNG stream for random-k index draws (disjoint from the
/// dataset/init/fault streams).
const COMPRESS_STREAM: u64 = 0xc0_11ec71;

/// Config-level compression selector.  `Copy` so the planner's `ScoreCtx`
/// and candidate set stay copyable.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Compression {
    /// Dense payloads; the exact legacy path (no wrapper is built).
    #[default]
    None,
    /// Keep the `ratio` fraction of coordinates with the largest
    /// magnitude (deterministic, ties toward the lower index).
    TopK { ratio: f64, ef: bool },
    /// Keep a seeded uniform sample of `ratio · n` coordinates.
    RandK { ratio: f64, ef: bool },
    /// 8-bit linear quantization (scale = max|acc| / 127).
    Q8 { ef: bool },
    /// 4-bit linear quantization (scale = max|acc| / 7).
    Q4 { ef: bool },
}

impl Compression {
    /// Parse `none | topk:RATIO | randk:RATIO | q8 | q4`, each with an
    /// optional trailing `:ef` / `:noef` (error feedback defaults to on).
    pub fn parse(s: &str) -> Result<Compression> {
        let mut parts = s.split(':');
        let head = parts.next().unwrap_or("");
        let mut rest: Vec<&str> = parts.collect();
        let ef = match rest.last() {
            Some(&"ef") => {
                rest.pop();
                true
            }
            Some(&"noef") => {
                rest.pop();
                false
            }
            _ => true,
        };
        let ratio_of = |rest: &[&str]| -> Result<f64> {
            let [r] = rest else {
                bail!("compression {s:?} wants exactly one ratio (e.g. topk:0.05)");
            };
            let ratio: f64 = r
                .parse()
                .map_err(|_| anyhow::anyhow!("bad compression ratio {r:?} in {s:?}"))?;
            if !(ratio > 0.0 && ratio <= 1.0) {
                bail!("compression ratio must be in (0, 1], got {ratio} in {s:?}");
            }
            Ok(ratio)
        };
        match head {
            "none" => {
                if !rest.is_empty() {
                    bail!("compression \"none\" takes no arguments, got {s:?}");
                }
                Ok(Compression::None)
            }
            "topk" => Ok(Compression::TopK { ratio: ratio_of(&rest)?, ef }),
            "randk" => Ok(Compression::RandK { ratio: ratio_of(&rest)?, ef }),
            "q8" => {
                if !rest.is_empty() {
                    bail!("compression \"q8\" takes no ratio, got {s:?}");
                }
                Ok(Compression::Q8 { ef })
            }
            "q4" => {
                if !rest.is_empty() {
                    bail!("compression \"q4\" takes no ratio, got {s:?}");
                }
                Ok(Compression::Q4 { ef })
            }
            _ => bail!("unknown compression {s:?} (none|topk:RATIO|randk:RATIO|q8|q4[:ef|:noef])"),
        }
    }

    /// Canonical spec string (round-trips through [`Compression::parse`];
    /// the default `ef = true` is omitted).
    pub fn spec(&self) -> String {
        let suffix = |ef: bool| if ef { "" } else { ":noef" };
        match self {
            Compression::None => "none".to_string(),
            Compression::TopK { ratio, ef } => format!("topk:{ratio}{}", suffix(*ef)),
            Compression::RandK { ratio, ef } => format!("randk:{ratio}{}", suffix(*ef)),
            Compression::Q8 { ef } => format!("q8{}", suffix(*ef)),
            Compression::Q4 { ef } => format!("q4{}", suffix(*ef)),
        }
    }

    pub fn is_none(&self) -> bool {
        matches!(self, Compression::None)
    }

    /// Selected coordinate count for sparse variants (`None`/quantized
    /// keep every coordinate).
    pub fn k_of(&self, n_params: usize) -> usize {
        match self {
            Compression::TopK { ratio, .. } | Compression::RandK { ratio, .. } => {
                ((ratio * n_params as f64).ceil() as usize).clamp(1, n_params.max(1))
            }
            _ => n_params,
        }
    }

    /// On-wire bytes of one learner's payload under this compression's
    /// wire format (see the module docs), capped at the dense `4·n` size.
    /// This is the `bytes` fed to the per-strategy allreduce formulas.
    pub fn payload_bytes(&self, n_params: usize) -> usize {
        let dense = n_params * 4;
        match self {
            Compression::None => dense,
            Compression::TopK { .. } | Compression::RandK { .. } => {
                // count header + (u32 index, f32 value) per selected coord
                dense.min(4 + 8 * self.k_of(n_params))
            }
            // f32 scale + u32 count header, then 1 byte per coordinate
            Compression::Q8 { .. } => dense.min(8 + n_params),
            // ... or a half byte per coordinate
            Compression::Q4 { .. } => dense.min(8 + n_params.div_ceil(2)),
        }
    }

    /// Multiplicative inflation applied to the gradient second-moment `M`
    /// in the Thm 3.4 budget bound when this compression is active — the
    /// accuracy side of the compression trade the planner scores (the
    /// bytes side is [`Compression::payload_bytes`]).
    ///
    /// Heuristic grounded in the EF-SGD analysis (Stich, Cordonnier &
    /// Jaggi, 2018): a δ-contraction compressor leaves a `(1 − δ)`
    /// fraction of the update in the residual each round.  With error
    /// feedback that mass is re-offered later and only inflates the
    /// variance-driven term — factor `1 + (1 − δ)/2`; without EF it is
    /// dropped outright and hits the bound harder — `1 + 2(1 − δ)`.
    /// Contraction per spec: sparse variants δ = keep ratio; linear
    /// quantization δ = 1 − 1/(2L) with L levels (127 for q8, 7 for q4).
    ///
    /// Guarantees relied on by the planner and its property tests:
    /// `None` returns *exactly* 1.0 (dense candidates score bit-identically
    /// whether or not a compression sweep rides along), q4 ≥ q8, `topk:R`
    /// strictly decreasing in R, and `noef` ≥ `ef` for any lossy spec.
    pub fn variance_inflation(&self) -> f64 {
        let (delta, ef) = match *self {
            Compression::None => return 1.0,
            Compression::TopK { ratio, ef } | Compression::RandK { ratio, ef } => (ratio, ef),
            Compression::Q8 { ef } => (1.0 - 1.0 / 254.0, ef),
            Compression::Q4 { ef } => (1.0 - 1.0 / 14.0, ef),
        };
        let lost = (1.0 - delta).max(0.0);
        if ef {
            1.0 + 0.5 * lost
        } else {
            1.0 + 2.0 * lost
        }
    }
}

/// One learner's compression pass: split `acc` into the transmitted
/// payload `t` and the error-feedback residual `e` (`acc == t + e`
/// coordinate-wise; bit-exact for the sparse variants, which copy selected
/// values verbatim).  With `ef = false` the residual is discarded (zeroed)
/// after the split.  Returns the number of coordinates transmitted.
///
/// Pure function of `(spec, acc, rng)` — the engine/thread layout never
/// sees it.  Exposed for the conservation tests and the bench.
pub fn compress_split(
    spec: Compression,
    acc: &[f32],
    t: &mut [f32],
    e: &mut [f32],
    rng: &mut Pcg32,
) -> usize {
    debug_assert_eq!(acc.len(), t.len());
    debug_assert_eq!(acc.len(), e.len());
    let n = acc.len();
    let sent = match spec {
        Compression::None => {
            t.copy_from_slice(acc);
            e.fill(0.0);
            n
        }
        Compression::TopK { .. } => {
            let k = spec.k_of(n);
            // Select the k largest |acc|, ties toward the lower index —
            // the total order (-|v|, i).  A partial selection
            // (`select_nth_unstable_by`) replaces the previous full sort:
            // because the comparator is a total order the k-smallest *set*
            // is unique, and only set membership feeds t/e below, so the
            // output is bit-identical to the sorted formulation at O(n)
            // average instead of O(n log n).
            let mut idx: Vec<u32> = (0..n as u32).collect();
            let cmp = |a: &u32, b: &u32| {
                let (ma, mb) = (acc[*a as usize].abs(), acc[*b as usize].abs());
                mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(b))
            };
            if k < n {
                idx.select_nth_unstable_by(k - 1, cmp);
            }
            t.fill(0.0);
            e.copy_from_slice(acc);
            for &i in &idx[..k] {
                t[i as usize] = acc[i as usize];
                e[i as usize] = 0.0;
            }
            k
        }
        Compression::RandK { .. } => {
            let k = spec.k_of(n);
            t.fill(0.0);
            e.copy_from_slice(acc);
            // Partial Fisher–Yates over an index array: the first k
            // positions are a uniform sample without replacement.
            let mut idx: Vec<u32> = (0..n as u32).collect();
            for i in 0..k.min(n.saturating_sub(1)) {
                let j = i + rng.next_below((n - i) as u32) as usize;
                idx.swap(i, j);
            }
            for &i in &idx[..k] {
                t[i as usize] = acc[i as usize];
                e[i as usize] = 0.0;
            }
            k
        }
        Compression::Q8 { .. } | Compression::Q4 { .. } => {
            let levels: f32 = if matches!(spec, Compression::Q8 { .. }) { 127.0 } else { 7.0 };
            // Magnitude scan + per-coordinate split on the vector kernels
            // (util::simd): lanes are coordinates, rounding semantics are
            // f32::round's exactly, so both dispatch paths agree bitwise.
            let max_abs = simd::max_abs(acc);
            if max_abs == 0.0 {
                t.fill(0.0);
                e.fill(0.0);
            } else {
                let scale = max_abs / levels;
                let inv = 1.0 / scale;
                simd::quantize_split(acc, t, e, inv, scale, levels);
            }
            n
        }
    };
    let keep_residual = match spec {
        Compression::None => false,
        Compression::TopK { ef, .. }
        | Compression::RandK { ef, .. }
        | Compression::Q8 { ef }
        | Compression::Q4 { ef } => ef,
    };
    if !keep_residual {
        e.fill(0.0);
    }
    sent
}

/// Per-learner compression state, shared between the collective wrapper
/// and the run's metrics (residual norms, payload accounting).
#[derive(Default)]
pub struct EfState {
    /// `ref_j`: the value learner j held right after its last compressed
    /// barrier (lazily initialized to its current value on first
    /// participation, which makes the first barrier exact).
    refs: Vec<FlatParams>,
    /// `e_j`: the error-feedback residual (empty = zero).
    residuals: Vec<FlatParams>,
    /// Per-learner barrier counter; seeds the random-k draw.
    rounds: Vec<u64>,
    /// Total coordinates transmitted across all barriers (diagnostics).
    pub coords_sent: u64,
    // Scratch buffers reused across barriers.
    acc: Vec<f32>,
    tx: Vec<f32>,
    tx_mean: Vec<f32>,
}

impl EfState {
    fn ensure(&mut self, p: usize) {
        if self.refs.len() < p {
            self.refs.resize(p, FlatParams::new());
            self.residuals.resize(p, FlatParams::new());
            self.rounds.resize(p, 0);
        }
    }

    /// Σ_j ‖e_j‖₂² over all learners (the un-transmitted mass currently
    /// held in residual accumulators), and its root.
    pub fn residual_l2(&self) -> f64 {
        self.residuals
            .iter()
            .flat_map(|e| e.iter())
            .map(|&v| (v as f64) * (v as f64))
            .sum::<f64>()
            .sqrt()
    }
}

/// A [`Collective`] wrapper that applies the compression transform at
/// every full-group barrier.  The wrapped engine's name is passed
/// through: compression is orthogonal to *how* the dense bookkeeping
/// moves.  `mean_of` (the paper's mid-interval w̃ probe) is a local read,
/// not a barrier — it delegates densely and touches no state.
pub struct CompressedCollective {
    inner: Box<dyn Collective>,
    spec: Compression,
    seed: u64,
    state: Arc<Mutex<EfState>>,
}

impl CompressedCollective {
    pub fn new(
        inner: Box<dyn Collective>,
        spec: Compression,
        seed: u64,
    ) -> (CompressedCollective, Arc<Mutex<EfState>>) {
        let state = Arc::new(Mutex::new(EfState::default()));
        (CompressedCollective { inner, spec, seed, state: Arc::clone(&state) }, state)
    }
}

impl Collective for CompressedCollective {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn average_group(&self, mut replicas: RowsMut<'_>, group: Range<usize>, scratch: &mut [f32]) {
        let n = scratch.len();
        let members = group.len();
        if members == 0 {
            return;
        }
        let mut st = self.state.lock().expect("compression state poisoned");
        let st = &mut *st;
        st.ensure(replicas.rows());
        st.acc.resize(n, 0.0);
        st.tx.resize(n, 0.0);
        st.tx_mean.resize(n, 0.0);
        // scratch accumulates mean_j(ref_j); tx_mean accumulates
        // mean_j(t_j).  Summation is learner-index ascending (the group
        // range is ascending), so the result is independent of engine.
        scratch.fill(0.0);
        st.tx_mean.fill(0.0);
        let inv = 1.0 / members as f32;
        for j in group.clone() {
            if st.refs[j].is_empty() {
                st.refs[j] = replicas.row(j)[..n].to_vec();
            }
            if st.residuals[j].is_empty() {
                st.residuals[j] = vec![0.0; n];
            }
            // acc_j = (x_j − ref_j) + e_j
            simd::delta_plus_residual(
                &mut st.acc,
                &replicas.row(j)[..n],
                &st.refs[j][..n],
                &st.residuals[j][..n],
            );
            let mut rng = Pcg32::new(
                self.seed ^ (j as u64).wrapping_mul(0x9e3779b97f4a7c15),
                COMPRESS_STREAM ^ st.rounds[j],
            );
            let residual = std::mem::take(&mut st.residuals[j]);
            let mut residual = residual;
            let sent = compress_split(self.spec, &st.acc, &mut st.tx, &mut residual, &mut rng);
            st.residuals[j] = residual;
            st.coords_sent += sent as u64;
            st.rounds[j] += 1;
            simd::add_assign(scratch, &st.refs[j][..n]);
            simd::add_assign(&mut st.tx_mean, &st.tx);
        }
        simd::scaled_sum(scratch, &st.tx_mean, inv);
        for j in group {
            replicas.row_mut(j)[..n].copy_from_slice(scratch);
            st.refs[j].copy_from_slice(scratch);
        }
    }

    fn mean_of(&self, replicas: Rows<'_>, group: Range<usize>, out: &mut [f32]) {
        self.inner.mean_of(replicas, group, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::collective::SimulatedCollective;
    use crate::params::ParamArena;

    fn vecs(p: usize, n: usize, seed: u64) -> ParamArena {
        let mut rng = Pcg32::seeded(seed);
        let rows: Vec<Vec<f32>> =
            (0..p).map(|_| (0..n).map(|_| rng.next_normal()).collect()).collect();
        ParamArena::from_rows(&rows)
    }

    #[test]
    fn parse_and_spec_roundtrip() {
        for s in ["none", "topk:0.05", "randk:0.25", "q8", "q4", "topk:0.1:noef", "q8:noef"] {
            let c = Compression::parse(s).unwrap();
            assert_eq!(c.spec(), s, "roundtrip {s}");
            assert_eq!(Compression::parse(&c.spec()).unwrap(), c);
        }
        assert_eq!(Compression::parse("topk:0.05:ef").unwrap(), Compression::parse("topk:0.05").unwrap());
        for bad in ["", "topk", "topk:0", "topk:2", "topk:x", "q8:0.5", "none:1", "zip", "randk:-0.1"] {
            assert!(Compression::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn payload_bytes_shapes() {
        let n = 1000;
        assert_eq!(Compression::None.payload_bytes(n), 4000);
        // topk 5% of 1000 = 50 coords: 4 + 50*8
        assert_eq!(Compression::parse("topk:0.05").unwrap().payload_bytes(n), 404);
        assert_eq!(Compression::parse("q8").unwrap().payload_bytes(n), 1008);
        assert_eq!(Compression::parse("q4").unwrap().payload_bytes(n), 508);
        // caps: sparse encoding of everything never exceeds dense
        assert_eq!(Compression::parse("topk:1").unwrap().payload_bytes(n), 4000);
        assert_eq!(Compression::parse("q8").unwrap().payload_bytes(1), 4);
        // k floors at one coordinate
        assert_eq!(Compression::parse("topk:0.001").unwrap().k_of(10), 1);
    }

    #[test]
    fn variance_inflation_orderings() {
        let f = |s: &str| Compression::parse(s).unwrap().variance_inflation();
        // Dense is exactly neutral — bit-stable planner scores depend on it.
        assert_eq!(f("none"), 1.0);
        // Keeping everything loses nothing.
        assert_eq!(f("topk:1"), 1.0);
        // Coarser quantization is penalized at least as much.
        assert!(f("q4") >= f("q8"), "q4 {} < q8 {}", f("q4"), f("q8"));
        assert!(f("q8") > 1.0 && f("q4") > 1.0);
        // topk:R penalty is monotone decreasing in R.
        let mut prev = f64::INFINITY;
        for r in ["0.01", "0.05", "0.1", "0.25", "0.5", "0.9"] {
            let v = f(&format!("topk:{r}"));
            assert!(v < prev, "topk:{r} inflation {v} not decreasing (prev {prev})");
            assert!(v >= 1.0);
            prev = v;
        }
        // Dropping the residual is never cheaper than keeping it.
        for s in ["topk:0.05", "randk:0.05", "q8", "q4"] {
            assert!(
                f(&format!("{s}:noef")) >= f(s),
                "noef should not be cheaper than ef for {s}"
            );
        }
        // randk and topk share the contraction model at equal ratio.
        assert_eq!(f("topk:0.05"), f("randk:0.05"));
    }

    #[test]
    fn topk_split_conserves_bit_exactly() {
        // residual + transmitted == accumulated payload, bit for bit —
        // the error-feedback conservation contract.
        let acc: Vec<f32> = {
            let mut rng = Pcg32::seeded(9);
            (0..257).map(|_| rng.next_normal()).collect()
        };
        let spec = Compression::parse("topk:0.05").unwrap();
        let (mut t, mut e) = (vec![0.0f32; acc.len()], vec![0.0f32; acc.len()]);
        let mut rng = Pcg32::seeded(1);
        let sent = compress_split(spec, &acc, &mut t, &mut e, &mut rng);
        assert_eq!(sent, spec.k_of(acc.len()));
        let mut nonzero = 0;
        for i in 0..acc.len() {
            // each coordinate lands wholly in t or wholly in e
            assert!(t[i].to_bits() == acc[i].to_bits() && e[i] == 0.0
                 || e[i].to_bits() == acc[i].to_bits() && t[i] == 0.0);
            if t[i] != 0.0 {
                nonzero += 1;
            }
        }
        assert!(nonzero <= sent);
        // the k selected really are the largest magnitudes
        let min_sent =
            t.iter().filter(|v| **v != 0.0).fold(f32::INFINITY, |m, &v| m.min(v.abs()));
        let max_kept = e.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(min_sent >= max_kept, "min_sent={min_sent} max_kept={max_kept}");
    }

    #[test]
    fn randk_is_seed_deterministic() {
        let acc: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let spec = Compression::parse("randk:0.25").unwrap();
        let run = |seed| {
            let (mut t, mut e) = (vec![0.0f32; 64], vec![0.0f32; 64]);
            let mut rng = Pcg32::seeded(seed);
            compress_split(spec, &acc, &mut t, &mut e, &mut rng);
            t
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn quantization_is_bounded_and_ef_captures_error() {
        let acc: Vec<f32> = {
            let mut rng = Pcg32::seeded(3);
            (0..500).map(|_| rng.next_normal()).collect()
        };
        for spec in [Compression::parse("q8").unwrap(), Compression::parse("q4").unwrap()] {
            let levels = if matches!(spec, Compression::Q8 { .. }) { 127.0f32 } else { 7.0 };
            let max_abs = acc.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let (mut t, mut e) = (vec![0.0f32; acc.len()], vec![0.0f32; acc.len()]);
            let mut rng = Pcg32::seeded(1);
            compress_split(spec, &acc, &mut t, &mut e, &mut rng);
            let half_step = 0.5 * max_abs / levels + 1e-6;
            for i in 0..acc.len() {
                assert!((t[i] - acc[i]).abs() <= half_step, "quantization error exceeds half a step");
                assert!((t[i] + e[i] - acc[i]).abs() <= 1e-6);
            }
        }
    }

    #[test]
    fn noef_discards_the_residual() {
        let acc: Vec<f32> = (0..32).map(|i| i as f32 - 16.0).collect();
        let spec = Compression::parse("topk:0.1:noef").unwrap();
        let (mut t, mut e) = (vec![0.0f32; 32], vec![0.0f32; 32]);
        let mut rng = Pcg32::seeded(1);
        compress_split(spec, &acc, &mut t, &mut e, &mut rng);
        assert!(e.iter().all(|&v| v == 0.0));
        assert!(t.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn first_barrier_is_exact_and_residuals_accumulate() {
        // Lazy reference init makes the first compressed barrier an exact
        // dense mean; afterwards the residual holds the untransmitted mass.
        let base = vecs(4, 64, 11);
        let mut dense = base.clone();
        let mut comp = base.clone();
        let mut s1 = vec![0.0f32; 64];
        let mut s2 = vec![0.0f32; 64];
        SimulatedCollective.average_group(dense.view_mut(), 0..4, &mut s1);
        let (cc, state) = CompressedCollective::new(
            Box::new(SimulatedCollective),
            Compression::parse("topk:0.05").unwrap(),
            42,
        );
        cc.average_group(comp.view_mut(), 0..4, &mut s2);
        for j in 0..4 {
            for i in 0..64 {
                assert!(
                    (comp.row(j)[i] - dense.row(j)[i]).abs() < 1e-6,
                    "first barrier ≈ dense mean"
                );
            }
        }
        assert_eq!(state.lock().unwrap().residual_l2(), 0.0, "nothing untransmitted yet");
        // Drift one learner and fire again: top-k keeps the big coords,
        // the rest lands in its residual.
        for i in 0..64 {
            comp.row_mut(2)[i] += (i as f32 + 1.0) * 0.01;
        }
        cc.average_group(comp.view_mut(), 0..4, &mut s2);
        assert!(state.lock().unwrap().residual_l2() > 0.0);
        // EF conservation end-to-end: transmitted mean + residual account
        // for the whole drift.  With one drifted learner the group mean
        // moved by mean(t_2)/1, and e_2 = drift − t_2.
        for j in [0, 1, 3] {
            assert_eq!(comp.row(j), comp.row(2), "barrier leaves members in agreement");
        }
    }

    #[test]
    fn repeated_barriers_drain_the_residual() {
        // With EF, repeated barriers over a static drift transmit it all:
        // the residual shrinks to zero and the mean converges to dense.
        let base = vecs(2, 40, 5);
        let mut dense = base.clone();
        let mut comp = base.clone();
        let mut s = vec![0.0f32; 40];
        SimulatedCollective.average_group(dense.view_mut(), 0..2, &mut s);
        let (cc, state) = CompressedCollective::new(
            Box::new(SimulatedCollective),
            Compression::parse("topk:0.2").unwrap(),
            42,
        );
        cc.average_group(comp.view_mut(), 0..2, &mut s); // exact (lazy refs)
        for i in 0..40 {
            comp.row_mut(0)[i] += 1.0; // drift
        }
        for _ in 0..8 {
            cc.average_group(comp.view_mut(), 0..2, &mut s);
        }
        // 20% per barrier × 8 barriers ≥ full coverage: residual drained
        assert!(state.lock().unwrap().residual_l2() < 1e-4);
        for i in 0..40 {
            assert!(
                (comp.row(0)[i] - (dense.row(0)[i] + 0.5)).abs() < 1e-4,
                "mean caught up with drift"
            );
        }
    }
}
