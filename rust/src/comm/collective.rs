//! The collective engine: *how* bytes move when a group of replicas is
//! averaged.  Extracted from the reducer so the cost model / statistics
//! (comm::reduce) and the schedule (algorithms) are independent of the
//! execution strategy — mirroring how torch.distributed separates process
//! groups from backend implementations.
//!
//! Three implementations:
//!
//! - [`SimulatedCollective`] — the original single-thread in-place path:
//!   blocked mean accumulation, then a broadcast copy per member.
//! - [`ShardedCollective`] — a reduce-scatter/all-gather analogue on OS
//!   threads: the flat parameter vector is cut into contiguous shards,
//!   worker threads reduce their shards concurrently, then the broadcast
//!   fans out over threads by member.  Spawns fresh scoped threads per
//!   call — kept as the reference parallel engine and the baseline the
//!   pooled engine is benchmarked against.
//! - [`PooledCollective`] — the same shard/broadcast decomposition
//!   dispatched onto a persistent [`exec::WorkerPool`], removing the
//!   per-reduction spawn+join, with a heuristic serial fallback so tiny
//!   groups/param counts skip the dispatch entirely.
//!
//! All compute the **identical** arithmetic: per element the summation is
//! learner-index-ascending (first replica copied, then pairs added in
//! order, then the scale), independent of the shard/block boundaries.
//! Results are therefore bit-identical across collectives and thread
//! counts — enforced by `prop_sharded_collective_bit_identical` and
//! `prop_pooled_collective_bit_identical` in rust/tests/hierarchy.rs.

use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::exec::{self, WorkerPool};
use crate::params::{Rows, RowsMut};
use crate::util::simd;

/// How a group of replicas is averaged in place.  Replicas are rows of the
/// trainer's flat learner arena (`params::Rows`/`RowsMut`) — a group is a
/// contiguous row range, so broadcasts and shard math work on one flat
/// slice.  Implementations must preserve the fixed learner-index-ascending
/// summation order so results are identical across engines.
pub trait Collective: Send + Sync {
    fn name(&self) -> &'static str;

    /// Average rows `group` of `replicas` and write the mean back into
    /// every member.  `scratch` (len = n_params) is the caller-owned mean
    /// buffer.
    fn average_group(&self, replicas: RowsMut<'_>, group: Range<usize>, scratch: &mut [f32]);

    /// Mean of rows `group` into `out` without touching the replicas.
    fn mean_of(&self, replicas: Rows<'_>, group: Range<usize>, out: &mut [f32]);
}

/// Which collective a run uses; the config-level selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveKind {
    /// Single-thread in-place reduction (the default; exact legacy path).
    Simulated,
    /// Thread-parallel sharded reduction on per-call scoped threads;
    /// `threads == 0` means auto (available parallelism).
    Sharded { threads: usize },
    /// Sharded reduction on the persistent worker pool; `threads == 0`
    /// defers to the run's `--pool-threads` (which itself defaults to
    /// available parallelism).
    Pooled { threads: usize },
}

impl CollectiveKind {
    pub fn parse(s: &str) -> Result<CollectiveKind> {
        match s {
            "simulated" => Ok(CollectiveKind::Simulated),
            "sharded" => Ok(CollectiveKind::Sharded { threads: 0 }),
            "pooled" => Ok(CollectiveKind::Pooled { threads: 0 }),
            other => {
                if let Some(t) = other.strip_prefix("sharded:") {
                    if let Ok(threads) = t.parse::<usize>() {
                        return Ok(CollectiveKind::Sharded { threads });
                    }
                }
                if let Some(t) = other.strip_prefix("pooled:") {
                    if let Ok(threads) = t.parse::<usize>() {
                        return Ok(CollectiveKind::Pooled { threads });
                    }
                }
                bail!(
                    "unknown collective {s:?} \
                     (simulated|sharded[:<threads>]|pooled[:<threads>])"
                )
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            CollectiveKind::Simulated => "simulated".to_string(),
            CollectiveKind::Sharded { threads: 0 } => "sharded".to_string(),
            CollectiveKind::Sharded { threads } => format!("sharded:{threads}"),
            CollectiveKind::Pooled { threads: 0 } => "pooled".to_string(),
            CollectiveKind::Pooled { threads } => format!("pooled:{threads}"),
        }
    }

    /// Build the engine, resolving a `Pooled { threads: 0 }` selector with
    /// the run's `--pool-threads` so the collective shares the same
    /// process-wide pool as the native backend's lane fan-out.  (There is
    /// deliberately no argument-free `build()`: a pooled kind built
    /// without the run's pool size would silently create a second
    /// full-size pool next to the run's own.)
    pub fn build_for(&self, pool_threads: usize) -> Box<dyn Collective> {
        match self {
            CollectiveKind::Simulated => Box::new(SimulatedCollective),
            CollectiveKind::Sharded { threads } => Box::new(ShardedCollective::new(*threads)),
            CollectiveKind::Pooled { threads } => {
                let t = if *threads > 0 { *threads } else { pool_threads };
                Box::new(PooledCollective::new(t))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Simulated (single-thread) collective
// ---------------------------------------------------------------------------

pub struct SimulatedCollective;

impl Collective for SimulatedCollective {
    fn name(&self) -> &'static str {
        "simulated"
    }

    fn average_group(&self, mut replicas: RowsMut<'_>, group: Range<usize>, scratch: &mut [f32]) {
        mean_range(scratch, replicas.as_shared(), group.clone(), 0);
        // Broadcast the mean back to every member.  §Perf note: a threaded
        // fan-out was tried here and reverted on single-hardware-thread
        // hosts; the sharded collective covers multi-core machines.
        for j in group {
            replicas.row_mut(j).copy_from_slice(scratch);
        }
    }

    fn mean_of(&self, replicas: Rows<'_>, group: Range<usize>, out: &mut [f32]) {
        mean_range(out, replicas, group, 0);
    }
}

// ---------------------------------------------------------------------------
// Sharded (thread-parallel) collective
// ---------------------------------------------------------------------------

/// Reduce-scatter/all-gather over OS threads: the flat vector is cut into
/// `threads` contiguous shards, each reduced concurrently (scoped threads,
/// same pattern as native/parallel.rs), then the broadcast fans out over
/// threads by member.  Per-element arithmetic is identical to
/// [`SimulatedCollective`] — only the loop over elements is parallel.
pub struct ShardedCollective {
    threads: usize,
}

impl ShardedCollective {
    /// `threads == 0` resolves to the host's available parallelism.
    pub fn new(threads: usize) -> ShardedCollective {
        ShardedCollective { threads }
    }

    fn resolve_threads(&self, n: usize) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        };
        t.clamp(1, n.max(1))
    }
}

impl Collective for ShardedCollective {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn average_group(&self, mut replicas: RowsMut<'_>, group: Range<usize>, scratch: &mut [f32]) {
        self.mean_of(replicas.as_shared(), group.clone(), scratch);
        // All-gather: split the member rows across threads; each copies
        // the full mean into its members.  A group is a contiguous row
        // range of the arena, so the members are one flat slice.
        let stride = replicas.stride();
        let members = group.len();
        let flat = replicas.range_mut(group);
        if members <= 1 {
            if !flat.is_empty() {
                flat.copy_from_slice(scratch);
            }
            return;
        }
        let mean: &[f32] = scratch;
        let t = self.resolve_threads(members);
        let per = members.div_ceil(t);
        std::thread::scope(|scope| {
            for chunk in flat.chunks_mut(per * stride) {
                scope.spawn(move || {
                    for r in chunk.chunks_exact_mut(stride) {
                        r.copy_from_slice(mean);
                    }
                });
            }
        });
    }

    fn mean_of(&self, replicas: Rows<'_>, group: Range<usize>, out: &mut [f32]) {
        let n = out.len();
        if n == 0 {
            return;
        }
        let t = self.resolve_threads(n);
        if t == 1 {
            mean_range(out, replicas, group, 0);
            return;
        }
        let shard = n.div_ceil(t);
        std::thread::scope(|scope| {
            for (i, m) in out.chunks_mut(shard).enumerate() {
                let group = group.clone();
                scope.spawn(move || mean_range(m, replicas, group, i * shard));
            }
        });
    }
}

// ---------------------------------------------------------------------------
// Pooled (persistent worker pool) collective
// ---------------------------------------------------------------------------

/// Below this many element-operations (group size × shard-able elements) a
/// reduction runs serially instead of paying the pool's notify/wait
/// round-trip.  At memory-bandwidth-bound throughput 64k element-ops take
/// tens of µs — an order of magnitude above the dispatch cost — so the
/// crossover errs toward serial, keeping tiny-group reductions (the common
/// case at the innermost hierarchy level) free of any dispatch overhead.
const POOL_MIN_ELEMENT_OPS: usize = 64 * 1024;

/// The same reduce-scatter/all-gather decomposition as
/// [`ShardedCollective`], dispatched onto a persistent [`WorkerPool`]
/// instead of freshly spawned scoped threads.  Shard boundaries use the
/// identical ceil-div math and [`mean_range`] is order-independent of
/// them, so results are bit-identical to both other collectives; small
/// reductions fall back to the serial kernel (see
/// [`POOL_MIN_ELEMENT_OPS`]).
pub struct PooledCollective {
    pool: Arc<WorkerPool>,
}

impl PooledCollective {
    /// A collective on the process-wide shared pool of `threads` slots
    /// (`0` = available parallelism); see [`exec::shared_pool`].
    pub fn new(threads: usize) -> PooledCollective {
        PooledCollective { pool: exec::shared_pool(threads) }
    }

    /// A collective on a specific pool (shared with other subsystems).
    pub fn with_pool(pool: Arc<WorkerPool>) -> PooledCollective {
        PooledCollective { pool }
    }
}

impl Collective for PooledCollective {
    fn name(&self) -> &'static str {
        "pooled"
    }

    fn average_group(&self, mut replicas: RowsMut<'_>, group: Range<usize>, scratch: &mut [f32]) {
        self.mean_of(replicas.as_shared(), group.clone(), scratch);
        let stride = replicas.stride();
        let members = group.len();
        let flat = replicas.range_mut(group);
        let n = scratch.len();
        if members * n < POOL_MIN_ELEMENT_OPS || members <= 1 {
            for r in flat.chunks_exact_mut(stride) {
                r.copy_from_slice(scratch);
            }
            return;
        }
        // All-gather: member rows are chunked across the pool (the group
        // is one contiguous arena slice, so chunk boundaries are row
        // multiples); each task copies the full mean into its members.
        let mean: &[f32] = scratch;
        let t = self.pool.threads().clamp(1, members);
        let per = members.div_ceil(t);
        self.pool.run_chunks_mut(flat, per * stride, |_, chunk| {
            for r in chunk.chunks_exact_mut(stride) {
                r.copy_from_slice(mean);
            }
        });
    }

    fn mean_of(&self, replicas: Rows<'_>, group: Range<usize>, out: &mut [f32]) {
        let n = out.len();
        if n == 0 {
            return;
        }
        let t = self.pool.threads().clamp(1, n);
        if t == 1 || group.len() * n < POOL_MIN_ELEMENT_OPS {
            mean_range(out, replicas, group, 0);
            return;
        }
        let shard = n.div_ceil(t);
        self.pool.run_chunks_mut(out, shard, |i, chunk| {
            mean_range(chunk, replicas, group.clone(), i * shard);
        });
    }
}

// ---------------------------------------------------------------------------
// The shared mean kernel
// ---------------------------------------------------------------------------

/// Cache-block size for the accumulation loop (floats; 16 KiB fits L1 with
/// room for two source streams).  §Perf: the naive formulation makes S
/// full passes over `out` (S+1 streams of DRAM traffic); blocking keeps the
/// accumulator chunk resident so `out` is written once, which measured
/// 1.6-2.3x faster at 3.4M params (see DESIGN.md §Performance).
const MEAN_BLOCK: usize = 4096;

/// `out = mean(replicas[group][base .. base + out.len()])` with fixed
/// (index-ascending) summation order.  `base` is the offset of the shard
/// within the flat vector; per-element arithmetic is independent of both
/// `base` and `MEAN_BLOCK` boundaries, which is what makes the sharded
/// collective bit-identical to the simulated one.
pub(crate) fn mean_range(out: &mut [f32], replicas: Rows<'_>, group: Range<usize>, base: usize) {
    let n = group.len();
    let first = group.start;
    if out.is_empty() || n == 0 {
        return;
    }
    if n == 1 {
        out.copy_from_slice(&replicas.row(first)[base..base + out.len()]);
        return;
    }
    let inv = 1.0 / n as f32;
    let len = out.len();
    let mut start = 0usize;
    while start < len {
        let end = (start + MEAN_BLOCK).min(len);
        let blk = &mut out[start..end];
        let (gs, ge) = (base + start, base + end);
        blk.copy_from_slice(&replicas.row(first)[gs..ge]);
        let mut rest = first + 1..group.end;
        // Pairs of sources per pass: halves the accumulator re-reads.
        // The vector kernels keep the exact scalar op sequence per
        // element — `(x + y)` then the accumulate, then one scale — see
        // util::simd's summation-order contract.
        while rest.len() >= 2 {
            let a = rest.next().unwrap();
            let b = rest.next().unwrap();
            simd::add_pair_assign(blk, &replicas.row(a)[gs..ge], &replicas.row(b)[gs..ge]);
        }
        if let Some(a) = rest.next() {
            simd::add_assign(blk, &replicas.row(a)[gs..ge]);
        }
        simd::scale_assign(blk, inv);
        start = end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::ParamArena;
    use crate::util::rng::Pcg32;

    fn replicas(p: usize, n: usize, seed: u64) -> ParamArena {
        let mut rng = Pcg32::seeded(seed);
        let rows: Vec<Vec<f32>> =
            (0..p).map(|_| (0..n).map(|_| rng.next_normal()).collect()).collect();
        ParamArena::from_rows(&rows)
    }

    #[test]
    fn simulated_group_mean_exact() {
        let rows: Vec<Vec<f32>> =
            (0..4).map(|j| (0..8).map(|i| (j * 8 + i) as f32).collect()).collect();
        let mut r = ParamArena::from_rows(&rows);
        let expect: Vec<f32> =
            (0..8).map(|i| (0..4).map(|j| (j * 8 + i) as f32).sum::<f32>() / 4.0).collect();
        let mut scratch = vec![0.0f32; 8];
        SimulatedCollective.average_group(r.view_mut(), 0..4, &mut scratch);
        for j in 0..4 {
            assert_eq!(r.row(j), &expect[..]);
        }
    }

    #[test]
    fn sharded_bit_identical_to_simulated() {
        for &(p, n, threads) in
            &[(2usize, 17usize, 2usize), (5, 1024, 3), (8, 9000, 4), (3, 4097, 7), (4, 1, 2)]
        {
            let base = replicas(p, n, 42 + p as u64);
            let mut a = base.clone();
            let mut b = base.clone();
            let mut sa = vec![0.0f32; n];
            let mut sb = vec![0.0f32; n];
            SimulatedCollective.average_group(a.view_mut(), 0..p, &mut sa);
            ShardedCollective::new(threads).average_group(b.view_mut(), 0..p, &mut sb);
            assert_eq!(a, b, "p={p} n={n} threads={threads}");
            assert_eq!(sa, sb);
            // subgroup averaging too
            if p >= 4 {
                let mut a = base.clone();
                let mut b = base.clone();
                SimulatedCollective.average_group(a.view_mut(), 1..3, &mut sa);
                ShardedCollective::new(threads).average_group(b.view_mut(), 1..3, &mut sb);
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn mean_of_does_not_mutate() {
        let r = replicas(3, 64, 7);
        let before = r.clone();
        let mut out_a = vec![0.0f32; 64];
        let mut out_b = vec![0.0f32; 64];
        let mut out_c = vec![0.0f32; 64];
        SimulatedCollective.mean_of(r.view(), 0..3, &mut out_a);
        ShardedCollective::new(2).mean_of(r.view(), 0..3, &mut out_b);
        PooledCollective::new(2).mean_of(r.view(), 0..3, &mut out_c);
        assert_eq!(r, before);
        assert_eq!(out_a, out_b);
        assert_eq!(out_a, out_c);
    }

    #[test]
    fn pooled_bit_identical_to_simulated() {
        // Shapes straddling the serial-fallback threshold on both sides
        // (group.len() * n vs POOL_MIN_ELEMENT_OPS) and odd shard splits.
        for &(p, n, threads) in &[
            (2usize, 17usize, 2usize),
            (4, 1, 2),
            (5, 1024, 3),
            (8, 9000, 4),
            (3, 4097, 7),
            (4, 50_000, 2),
            (2, 100_003, 5),
        ] {
            let base = replicas(p, n, 77 + p as u64);
            let mut a = base.clone();
            let mut b = base.clone();
            let mut sa = vec![0.0f32; n];
            let mut sb = vec![0.0f32; n];
            SimulatedCollective.average_group(a.view_mut(), 0..p, &mut sa);
            PooledCollective::new(threads).average_group(b.view_mut(), 0..p, &mut sb);
            assert_eq!(a, b, "p={p} n={n} threads={threads}");
            assert_eq!(sa, sb);
            if p >= 4 {
                let mut a = base.clone();
                let mut b = base.clone();
                SimulatedCollective.average_group(a.view_mut(), 1..3, &mut sa);
                PooledCollective::new(threads).average_group(b.view_mut(), 1..3, &mut sb);
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn kind_parse_and_name() {
        assert_eq!(CollectiveKind::parse("simulated").unwrap(), CollectiveKind::Simulated);
        assert_eq!(
            CollectiveKind::parse("sharded").unwrap(),
            CollectiveKind::Sharded { threads: 0 }
        );
        assert_eq!(
            CollectiveKind::parse("sharded:4").unwrap(),
            CollectiveKind::Sharded { threads: 4 }
        );
        assert_eq!(
            CollectiveKind::parse("pooled").unwrap(),
            CollectiveKind::Pooled { threads: 0 }
        );
        assert_eq!(
            CollectiveKind::parse("pooled:6").unwrap(),
            CollectiveKind::Pooled { threads: 6 }
        );
        assert!(CollectiveKind::parse("mpi").is_err());
        assert!(CollectiveKind::parse("sharded:x").is_err());
        assert!(CollectiveKind::parse("pooled:x").is_err());
        assert_eq!(CollectiveKind::Sharded { threads: 4 }.name(), "sharded:4");
        assert_eq!(CollectiveKind::Pooled { threads: 4 }.name(), "pooled:4");
        assert_eq!(CollectiveKind::Pooled { threads: 0 }.name(), "pooled");
        assert_eq!(CollectiveKind::Simulated.name(), "simulated");
    }

    #[test]
    fn build_for_resolves_pool_threads() {
        // Pooled{0} defers to the run-level pool-threads knob; explicit
        // counts win.  Either way the engine reports the pooled name.
        let c = CollectiveKind::Pooled { threads: 0 }.build_for(2);
        assert_eq!(c.name(), "pooled");
        let c = CollectiveKind::Pooled { threads: 3 }.build_for(2);
        assert_eq!(c.name(), "pooled");
        let c = CollectiveKind::Simulated.build_for(4);
        assert_eq!(c.name(), "simulated");
    }
}
