//! The step-execution backend interface.
//!
//! Two implementations:
//! - `runtime::XlaBackend` — loads the AOT-lowered HLO artifacts (JAX L2 +
//!   Pallas L1) and executes them through the PJRT CPU client.  The
//!   production path.
//! - `native::NativeMlp` — a pure-Rust MLP with hand-written backprop.
//!   A substrate for tests (exact cross-validation of the XLA numerics),
//!   property sweeps, and fast large-P experiments.

use anyhow::Result;

use crate::data::BatchBuf;
use crate::params::{FlatParams, Rows, RowsMut};

/// Per-learner outputs of one training step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepOut {
    /// Mean loss over the learner's mini-batch.
    pub loss: f32,
    /// Correct predictions in the mini-batch (classification) or over all
    /// tokens (LM).
    pub ncorrect: f32,
}

// Not `Send`: the XLA implementation holds PJRT handles (raw pointers).
// The trainer is single-threaded over the backend; parallelism lives
// inside the backend (stacked dispatch) and in the reducer.
pub trait StepBackend {
    /// Per-learner train mini-batch size B.
    fn train_batch(&self) -> usize;
    /// Eval batch size.
    fn eval_batch(&self) -> usize;
    /// Flat parameter count.
    fn n_params(&self) -> usize;

    /// Compute gradients for all P learners.  `batch` holds P·B rows in
    /// learner order; `grads_out` row j receives learner j's flat
    /// gradient.  Views are arena rows (`params::Rows`/`RowsMut`) so
    /// backends read replicas and write gradients zero-copy out of the
    /// trainer's flat learner arenas.
    fn grads(
        &mut self,
        replicas: Rows<'_>,
        batch: &BatchBuf,
        grads_out: RowsMut<'_>,
        outs: &mut [StepOut],
    ) -> Result<()>;

    /// Evaluate one batch on a single parameter vector; returns
    /// (sum_loss, ncorrect) over the `n` valid rows (the batch may be
    /// padded up to `eval_batch()` rows — implementations must ignore the
    /// padding rows).
    fn eval_batch_stats(
        &mut self,
        params: &FlatParams,
        batch: &BatchBuf,
        n: usize,
    ) -> Result<(f32, f32)>;

    /// Units per row for loss/accuracy normalization (1 for classification,
    /// seq_len for LM token-level metrics).
    fn units_per_row(&self) -> usize {
        1
    }
}
