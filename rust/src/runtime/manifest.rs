//! artifacts/manifest.json — the contract between the Python compile path
//! and the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::params::{load_init_blob, FlatParams, ParamLayout};
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub enum ModelKind {
    Mlp { dims: Vec<usize>, activation: String },
    Lm { vocab: usize, d_model: usize, n_layers: usize, n_heads: usize, seq_len: usize },
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub kind: ModelKind,
    pub batch: usize,
    pub eval_batch: usize,
    pub layout: ParamLayout,
    /// P -> train artifact file (relative to the artifacts dir).
    pub train_files: BTreeMap<usize, String>,
    pub eval_file: String,
    pub init_file: String,
    pub seed: u64,
}

impl ModelEntry {
    pub fn input_dim(&self) -> Option<usize> {
        match &self.kind {
            ModelKind::Mlp { dims, .. } => Some(dims[0]),
            ModelKind::Lm { .. } => None,
        }
    }

    pub fn classes(&self) -> Option<usize> {
        match &self.kind {
            ModelKind::Mlp { dims, .. } => dims.last().copied(),
            ModelKind::Lm { .. } => None,
        }
    }

    /// Largest exported stacked-P variant `<= p`, used when the exact P is
    /// unavailable (the runtime then loops the variant).
    pub fn best_train_p(&self, p: usize) -> Option<usize> {
        self.train_files.keys().copied().filter(|&k| k <= p && p % k == 0).max()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
    /// Group-average artifacts: S -> file, plus the chunk length.
    pub avg_groups: BTreeMap<usize, String>,
    pub avg_chunk: usize,
    /// Optional fused-SGD-update artifact (chunk, file).
    pub sgd_update: Option<(usize, String)>,
}

impl Manifest {
    /// Default artifacts directory: $HIER_AVG_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("HIER_AVG_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn load_default() -> Result<Manifest> {
        Manifest::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} (run `make artifacts` first)", path.display())
        })?;
        let j = Json::parse(&text)?;
        let version = j.req("format_version")?.as_usize()?;
        if version != 1 {
            bail!("unsupported manifest format_version {version}");
        }
        let mut models = BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj()? {
            let kind = match m.req("kind")?.as_str()? {
                "mlp" => ModelKind::Mlp {
                    dims: m.req("dims")?.usize_arr()?,
                    activation: m.req("activation")?.as_str()?.to_string(),
                },
                "lm" => ModelKind::Lm {
                    vocab: m.req("vocab")?.as_usize()?,
                    d_model: m.req("d_model")?.as_usize()?,
                    n_layers: m.req("n_layers")?.as_usize()?,
                    n_heads: m.req("n_heads")?.as_usize()?,
                    seq_len: m.req("seq_len")?.as_usize()?,
                },
                k => bail!("unknown model kind {k:?}"),
            };
            let mut train_files = BTreeMap::new();
            for (p, f) in m.req("train")?.as_obj()? {
                train_files.insert(p.parse::<usize>()?, f.as_str()?.to_string());
            }
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    kind,
                    batch: m.req("batch")?.as_usize()?,
                    eval_batch: m.req("eval_batch")?.as_usize()?,
                    layout: ParamLayout::from_json(m.req("params")?)?,
                    train_files,
                    eval_file: m.req("eval")?.as_str()?.to_string(),
                    init_file: m.req("init")?.as_str()?.to_string(),
                    seed: m.req("seed")?.as_usize()? as u64,
                },
            );
        }
        let avg = j.req("avg")?;
        let mut avg_groups = BTreeMap::new();
        for (s, f) in avg.req("groups")?.as_obj()? {
            avg_groups.insert(s.parse::<usize>()?, f.as_str()?.to_string());
        }
        let sgd_update = match j.get("sgd_update") {
            Some(v) => Some((
                v.req("chunk")?.as_usize()?,
                v.req("file")?.as_str()?.to_string(),
            )),
            None => None,
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            models,
            avg_groups,
            avg_chunk: avg.req("chunk")?.as_usize()?,
            sgd_update,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "model {name:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn file(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }

    /// Load a model's synchronized initial parameters.
    pub fn load_init(&self, entry: &ModelEntry) -> Result<FlatParams> {
        load_init_blob(&self.file(&entry.init_file), &entry.layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load_default().unwrap();
        assert!(m.models.contains_key("quickstart"));
        let e = m.model("resnet18_sim").unwrap();
        match &e.kind {
            ModelKind::Mlp { dims, .. } => assert_eq!(dims[0], 128),
            _ => panic!("resnet18_sim should be an MLP"),
        }
        assert!(e.train_files.contains_key(&1));
        assert!(e.layout.total > 0);
        // init blob parses and matches the layout
        let init = m.load_init(e).unwrap();
        assert_eq!(init.len(), e.layout.total);
        // weights are non-degenerate
        let nz = init.iter().filter(|v| **v != 0.0).count();
        assert!(nz > init.len() / 4);
    }

    #[test]
    fn best_train_p() {
        let mut e = ModelEntry {
            name: "x".into(),
            kind: ModelKind::Mlp { dims: vec![2, 2], activation: "relu".into() },
            batch: 1,
            eval_batch: 1,
            layout: crate::params::ParamLayout::from_entries(vec![]).unwrap(),
            train_files: BTreeMap::new(),
            eval_file: String::new(),
            init_file: String::new(),
            seed: 0,
        };
        e.train_files.insert(1, "a".into());
        e.train_files.insert(16, "b".into());
        assert_eq!(e.best_train_p(16), Some(16));
        assert_eq!(e.best_train_p(32), Some(16));
        assert_eq!(e.best_train_p(8), Some(1));
        assert_eq!(e.best_train_p(3), Some(1));
    }

    #[test]
    fn missing_model_error_lists_names() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load_default().unwrap();
        let err = m.model("nope").unwrap_err().to_string();
        assert!(err.contains("quickstart"));
    }
}
