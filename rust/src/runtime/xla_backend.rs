//! PJRT-backed `StepBackend`: packs learner state into XLA literals,
//! executes the AOT train/eval artifacts, and scatters gradients back into
//! the coordinator's flat buffers.
//!
//! One *stacked* dispatch carries `train_p` learners (leading dimension P
//! in every input/output); when the run's P exceeds the largest exported
//! variant the backend loops over chunks.  Python is never invoked.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::backend::{StepBackend, StepOut};
use crate::data::BatchBuf;
use crate::params::{FlatParams, Rows, RowsMut};
use crate::runtime::manifest::{Manifest, ModelEntry, ModelKind};

thread_local! {
    /// One PJRT CPU client + compiled-executable cache per thread: sweeps
    /// (the repro harness runs dozens of configs in one process) pay HLO
    /// compilation once per artifact instead of once per run.
    static RUNTIME: RefCell<Option<XlaRuntime>> = const { RefCell::new(None) };
}

/// Shared PJRT client + artifact loader with a compile cache.
#[derive(Clone)]
pub struct XlaRuntime {
    pub client: xla::PjRtClient,
    cache: Rc<RefCell<HashMap<PathBuf, Rc<xla::PjRtLoadedExecutable>>>>,
}

impl XlaRuntime {
    /// Fresh client (no sharing).  Prefer [`XlaRuntime::cpu_shared`].
    pub fn cpu() -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime { client, cache: Rc::new(RefCell::new(HashMap::new())) })
    }

    /// The thread's shared client + compile cache.
    pub fn cpu_shared() -> Result<XlaRuntime> {
        RUNTIME.with(|slot| {
            let mut slot = slot.borrow_mut();
            if slot.is_none() {
                *slot = Some(XlaRuntime::cpu()?);
            }
            Ok(slot.as_ref().unwrap().clone())
        })
    }

    /// Host -> device buffer (f32).  NOTE: all executions go through
    /// `execute_b` with caller-owned buffers: the crate's literal-based
    /// `execute` leaks its input device buffers (the C++ shim `release()`s
    /// them and never frees — verified empirically, ~input-size bytes per
    /// call), so it must not be used on the hot path.
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Host -> device buffer (i32).
    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Load an HLO-text artifact and compile it (cached by path).
    pub fn load_hlo(&self, path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache.borrow_mut().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }
}

pub struct XlaBackend {
    runtime: XlaRuntime,
    pub entry: ModelEntry,
    train_exe: Rc<xla::PjRtLoadedExecutable>,
    /// Learners per stacked dispatch.
    train_p: usize,
    eval_exe: Rc<xla::PjRtLoadedExecutable>,
    /// Packing scratch, reused across steps.
    pack: Vec<f32>,
}

impl XlaBackend {
    /// Load the best stacked-train variant for `p` learners plus the eval
    /// artifact for `model`.
    pub fn load(manifest: &Manifest, model: &str, p: usize) -> Result<XlaBackend> {
        let entry = manifest.model(model)?.clone();
        let train_p = entry.best_train_p(p).ok_or_else(|| {
            anyhow::anyhow!(
                "no stacked train artifact divides P={p} for {model} (have {:?})",
                entry.train_files.keys().collect::<Vec<_>>()
            )
        })?;
        let runtime = XlaRuntime::cpu_shared()?;
        let train_exe = runtime.load_hlo(&manifest.file(&entry.train_files[&train_p]))?;
        let eval_exe = runtime.load_hlo(&manifest.file(&entry.eval_file))?;
        Ok(XlaBackend { runtime, entry, train_exe, train_p, eval_exe, pack: Vec::new() })
    }

    pub fn train_p(&self) -> usize {
        self.train_p
    }

    pub fn runtime(&self) -> &XlaRuntime {
        &self.runtime
    }

    fn is_lm(&self) -> bool {
        matches!(self.entry.kind, ModelKind::Lm { .. })
    }

    fn seq_len(&self) -> usize {
        match &self.entry.kind {
            ModelKind::Lm { seq_len, .. } => *seq_len,
            _ => 1,
        }
    }

    fn input_dim(&self) -> usize {
        match &self.entry.kind {
            ModelKind::Mlp { dims, .. } => dims[0],
            ModelKind::Lm { seq_len, .. } => *seq_len,
        }
    }

    /// Stacked device buffer for tensor `i` of layout over learners
    /// `chunk_start..chunk_start+pc`.
    fn pack_param(
        &mut self,
        replicas: Rows<'_>,
        chunk_start: usize,
        pc: usize,
        i: usize,
    ) -> Result<xla::PjRtBuffer> {
        let e = &self.entry.layout.entries[i];
        self.pack.clear();
        for j in chunk_start..chunk_start + pc {
            self.pack.extend_from_slice(&replicas.row(j)[e.offset..e.offset + e.size]);
        }
        let mut dims: Vec<usize> = Vec::with_capacity(e.shape.len() + 1);
        if pc > 1 || self.train_p > 1 {
            dims.push(pc);
        }
        dims.extend_from_slice(&e.shape);
        self.runtime.buf_f32(&self.pack, &dims)
    }

    fn single_param(&self, params: &FlatParams, i: usize) -> Result<xla::PjRtBuffer> {
        let e = &self.entry.layout.entries[i];
        self.runtime.buf_f32(&params[e.offset..e.offset + e.size], &e.shape)
    }

    /// Batch device buffers (x, y) for `pc` learners × `b` rows.
    fn batch_buffers(
        &self,
        batch: &BatchBuf,
        row_start: usize,
        pc: usize,
        b: usize,
        stacked: bool,
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let t = self.seq_len();
        let rows = pc * b;
        if self.is_lm() {
            let xs = &batch.xi[row_start * t..(row_start + rows) * t];
            let ys = &batch.y[row_start * t..(row_start + rows) * t];
            let dims: Vec<usize> =
                if stacked { vec![pc, b, t] } else { vec![b, t] };
            Ok((self.runtime.buf_i32(xs, &dims)?, self.runtime.buf_i32(ys, &dims)?))
        } else {
            let d = self.input_dim();
            let xs = &batch.xf[row_start * d..(row_start + rows) * d];
            let ys = &batch.y[row_start..row_start + rows];
            let (xd, yd): (Vec<usize>, Vec<usize>) = if stacked {
                (vec![pc, b, d], vec![pc, b])
            } else {
                (vec![b, d], vec![b])
            };
            Ok((self.runtime.buf_f32(xs, &xd)?, self.runtime.buf_i32(ys, &yd)?))
        }
    }

    /// Execute one stacked chunk and scatter outputs.
    fn run_chunk(
        &mut self,
        replicas: Rows<'_>,
        batch: &BatchBuf,
        chunk_start: usize,
        pc: usize,
        grads_out: &mut RowsMut<'_>,
        outs: &mut [StepOut],
    ) -> Result<()> {
        let n_tensors = self.entry.layout.n_tensors();
        let b = self.entry.batch;
        let mut inputs: Vec<xla::PjRtBuffer> = Vec::with_capacity(n_tensors + 2);
        for i in 0..n_tensors {
            inputs.push(self.pack_param(replicas, chunk_start, pc, i)?);
        }
        let (x, y) = self.batch_buffers(batch, chunk_start * b, pc, b, self.train_p > 1)?;
        inputs.push(x);
        inputs.push(y);

        let result = self.train_exe.execute_b::<xla::PjRtBuffer>(&inputs)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != n_tensors + 2 {
            bail!("train artifact returned {} outputs, expected {}", parts.len(), n_tensors + 2);
        }
        // Scatter gradients.
        for (i, part) in parts[..n_tensors].iter().enumerate() {
            let e = &self.entry.layout.entries[i];
            let vals = part.to_vec::<f32>()?;
            if vals.len() != pc * e.size {
                bail!("grad {} has {} values, expected {}", e.name, vals.len(), pc * e.size);
            }
            for (c, chunk) in vals.chunks_exact(e.size).enumerate() {
                grads_out.row_mut(chunk_start + c)[e.offset..e.offset + e.size]
                    .copy_from_slice(chunk);
            }
        }
        let losses = parts[n_tensors].to_vec::<f32>()?;
        let ncorrect = parts[n_tensors + 1].to_vec::<f32>()?;
        for c in 0..pc {
            outs[chunk_start + c] =
                StepOut { loss: losses[c.min(losses.len() - 1)], ncorrect: ncorrect[c.min(ncorrect.len() - 1)] };
        }
        Ok(())
    }
}

impl StepBackend for XlaBackend {
    fn train_batch(&self) -> usize {
        self.entry.batch
    }

    fn eval_batch(&self) -> usize {
        self.entry.eval_batch
    }

    fn n_params(&self) -> usize {
        self.entry.layout.total
    }

    fn units_per_row(&self) -> usize {
        self.seq_len()
    }

    fn grads(
        &mut self,
        replicas: Rows<'_>,
        batch: &BatchBuf,
        mut grads_out: RowsMut<'_>,
        outs: &mut [StepOut],
    ) -> Result<()> {
        let p = replicas.rows();
        if p % self.train_p != 0 {
            bail!("P={p} not a multiple of the loaded stacked variant ({})", self.train_p);
        }
        if batch.rows != p * self.entry.batch {
            bail!("batch rows {} != P*B = {}", batch.rows, p * self.entry.batch);
        }
        for chunk in 0..p / self.train_p {
            self.run_chunk(
                replicas,
                batch,
                chunk * self.train_p,
                self.train_p,
                &mut grads_out,
                outs,
            )?;
        }
        Ok(())
    }

    fn eval_batch_stats(
        &mut self,
        params: &FlatParams,
        batch: &BatchBuf,
        n: usize,
    ) -> Result<(f32, f32)> {
        if n != self.entry.eval_batch {
            bail!("XLA eval requires full batches of {} rows (got {n})", self.entry.eval_batch);
        }
        let n_tensors = self.entry.layout.n_tensors();
        let mut inputs: Vec<xla::PjRtBuffer> = Vec::with_capacity(n_tensors + 2);
        for i in 0..n_tensors {
            inputs.push(self.single_param(params, i)?);
        }
        let t = self.seq_len();
        let (x, y) = if self.is_lm() {
            (
                self.runtime.buf_i32(&batch.xi[..n * t], &[n, t])?,
                self.runtime.buf_i32(&batch.y[..n * t], &[n, t])?,
            )
        } else {
            let d = self.input_dim();
            (
                self.runtime.buf_f32(&batch.xf[..n * d], &[n, d])?,
                self.runtime.buf_i32(&batch.y[..n], &[n])?,
            )
        };
        inputs.push(x);
        inputs.push(y);
        let result =
            self.eval_exe.execute_b::<xla::PjRtBuffer>(&inputs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 2 {
            bail!("eval artifact returned {} outputs, expected 2", parts.len());
        }
        Ok((parts[0].get_first_element::<f32>()?, parts[1].get_first_element::<f32>()?))
    }
}

/// The Pallas group-average artifact (avg_s<S>.hlo.txt): averages S
/// parameter shards chunk-by-chunk through XLA.  The alternate reduction
/// path benchmarked against the native reducer in benches/reduction.rs.
pub struct XlaGroupAvg {
    runtime: XlaRuntime,
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub s: usize,
    pub chunk: usize,
    pack: Vec<f32>,
}

impl XlaGroupAvg {
    pub fn load(manifest: &Manifest, s: usize) -> Result<XlaGroupAvg> {
        let file = manifest
            .avg_groups
            .get(&s)
            .ok_or_else(|| anyhow::anyhow!("no avg artifact for S={s}"))?;
        let runtime = XlaRuntime::cpu_shared()?;
        let exe = runtime.load_hlo(&manifest.file(file))?;
        Ok(XlaGroupAvg { runtime, exe, s, chunk: manifest.avg_chunk, pack: Vec::new() })
    }

    /// out = mean of `shards` (each len n), processed in CHUNK blocks.
    /// Tails shorter than a chunk are zero-padded (mean of padding is
    /// discarded).
    pub fn average(&mut self, shards: &[&[f32]], out: &mut [f32]) -> Result<()> {
        if shards.len() != self.s {
            bail!("expected {} shards, got {}", self.s, shards.len());
        }
        let n = out.len();
        let c = self.chunk;
        let mut start = 0usize;
        while start < n {
            let len = c.min(n - start);
            self.pack.clear();
            for sh in shards {
                self.pack.extend_from_slice(&sh[start..start + len]);
                self.pack.extend(std::iter::repeat(0.0).take(c - len));
            }
            let buf = self.runtime.buf_f32(&self.pack, &[self.s, c])?;
            let result =
                self.exe.execute_b::<xla::PjRtBuffer>(&[buf])?[0][0].to_literal_sync()?;
            let mean = result.to_tuple1()?.to_vec::<f32>()?;
            out[start..start + len].copy_from_slice(&mean[..len]);
            start += len;
        }
        let _ = &self.runtime;
        Ok(())
    }
}

/// The fused Pallas SGD-update artifact: `w -= lr * g` chunk by chunk
/// through XLA.  Alternate path to the native `optimizer::Sgd`, compared in
/// benches/reduction.rs.
pub struct XlaSgdUpdate {
    runtime: XlaRuntime,
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub chunk: usize,
}

impl XlaSgdUpdate {
    pub fn load(manifest: &Manifest) -> Result<XlaSgdUpdate> {
        let Some((chunk, file)) = &manifest.sgd_update else {
            bail!("manifest has no sgd_update artifact (rebuild artifacts)");
        };
        let runtime = XlaRuntime::cpu_shared()?;
        let exe = runtime.load_hlo(&manifest.file(file))?;
        Ok(XlaSgdUpdate { runtime, exe, chunk: *chunk })
    }

    /// In-place `w -= lr * g` (tail chunks zero-padded).
    pub fn apply(&mut self, w: &mut [f32], g: &[f32], lr: f32) -> Result<()> {
        anyhow::ensure!(w.len() == g.len(), "w/g length mismatch");
        let c = self.chunk;
        let mut start = 0usize;
        let mut wpad = vec![0.0f32; c];
        let mut gpad = vec![0.0f32; c];
        while start < w.len() {
            let len = c.min(w.len() - start);
            wpad[..len].copy_from_slice(&w[start..start + len]);
            wpad[len..].fill(0.0);
            gpad[..len].copy_from_slice(&g[start..start + len]);
            gpad[len..].fill(0.0);
            let wl = self.runtime.buf_f32(&wpad, &[c])?;
            let gl = self.runtime.buf_f32(&gpad, &[c])?;
            let lr_buf = self.runtime.buf_f32(std::slice::from_ref(&lr), &[])?;
            let result = self.exe.execute_b::<xla::PjRtBuffer>(&[wl, gl, lr_buf])?[0][0]
                .to_literal_sync()?;
            let out = result.to_tuple1()?.to_vec::<f32>()?;
            w[start..start + len].copy_from_slice(&out[..len]);
            start += len;
        }
        let _ = &self.runtime;
        Ok(())
    }
}
