//! Stub XLA runtime, compiled when the `xla` cargo feature is off.
//!
//! Mirrors the public surface of `xla_backend.rs` (the PJRT-backed
//! implementation) so the driver, benches, and integration tests build
//! without the vendored `xla` crate; every constructor returns an error,
//! and since nothing can be constructed the method bodies are
//! unreachable-but-typechecked.  The XLA integration tests already skip
//! when artifacts are missing, so `cargo test` stays green.

use anyhow::{bail, Result};

use crate::backend::{StepBackend, StepOut};
use crate::data::BatchBuf;
use crate::params::{FlatParams, Rows, RowsMut};
use crate::runtime::manifest::Manifest;

const UNAVAILABLE: &str = "built without the `xla` feature: the PJRT runtime is unavailable \
     (vendor the `xla` crate and rebuild with `--features xla`)";

/// Stub of the shared PJRT client + compile cache.
#[derive(Clone)]
pub struct XlaRuntime {
    _private: (),
}

impl XlaRuntime {
    pub fn cpu() -> Result<XlaRuntime> {
        bail!(UNAVAILABLE)
    }

    pub fn cpu_shared() -> Result<XlaRuntime> {
        bail!(UNAVAILABLE)
    }
}

/// Stub of the PJRT-backed `StepBackend`.
pub struct XlaBackend {
    _private: (),
}

impl XlaBackend {
    pub fn load(_manifest: &Manifest, _model: &str, _p: usize) -> Result<XlaBackend> {
        bail!(UNAVAILABLE)
    }

    pub fn train_p(&self) -> usize {
        0
    }
}

impl StepBackend for XlaBackend {
    fn train_batch(&self) -> usize {
        0
    }

    fn eval_batch(&self) -> usize {
        0
    }

    fn n_params(&self) -> usize {
        0
    }

    fn grads(
        &mut self,
        _replicas: Rows<'_>,
        _batch: &BatchBuf,
        _grads_out: RowsMut<'_>,
        _outs: &mut [StepOut],
    ) -> Result<()> {
        bail!(UNAVAILABLE)
    }

    fn eval_batch_stats(
        &mut self,
        _params: &FlatParams,
        _batch: &BatchBuf,
        _n: usize,
    ) -> Result<(f32, f32)> {
        bail!(UNAVAILABLE)
    }
}

/// Stub of the Pallas group-average artifact runner.
pub struct XlaGroupAvg {
    pub s: usize,
    pub chunk: usize,
}

impl XlaGroupAvg {
    pub fn load(_manifest: &Manifest, _s: usize) -> Result<XlaGroupAvg> {
        bail!(UNAVAILABLE)
    }

    pub fn average(&mut self, _shards: &[&[f32]], _out: &mut [f32]) -> Result<()> {
        bail!(UNAVAILABLE)
    }
}

/// Stub of the fused Pallas SGD-update artifact runner.
pub struct XlaSgdUpdate {
    pub chunk: usize,
}

impl XlaSgdUpdate {
    pub fn load(_manifest: &Manifest) -> Result<XlaSgdUpdate> {
        bail!(UNAVAILABLE)
    }

    pub fn apply(&mut self, _w: &mut [f32], _g: &[f32], _lr: f32) -> Result<()> {
        bail!(UNAVAILABLE)
    }
}
