//! The XLA/PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! HLO **text** is the interchange format (see aot.py's module docs): the
//! text parser reassigns instruction ids, sidestepping xla_extension
//! 0.5.1's 32-bit id limit on jax ≥ 0.5 protos.

pub mod manifest;

// The real PJRT path needs the `xla` crate (vendored; see Cargo.toml).
// Without the feature a stub with the same public surface compiles in, so
// the rest of the crate (driver, benches, tests) builds offline and every
// XLA entry point returns a load-time error instead.
#[cfg(feature = "xla")]
pub mod xla_backend;
#[cfg(not(feature = "xla"))]
#[path = "xla_stub.rs"]
pub mod xla_backend;

pub use manifest::{Manifest, ModelEntry, ModelKind};
pub use xla_backend::{XlaBackend, XlaRuntime};
