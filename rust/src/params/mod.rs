//! Flat parameter buffers and their tensor layout.
//!
//! Every learner replica holds one contiguous `Vec<f32>` with all model
//! parameters.  `ParamLayout` (mirroring `artifacts/manifest.json`) maps
//! tensor names to (shape, offset, len) so the XLA runtime can slice the
//! buffer into per-tensor literals in exactly the order the AOT-lowered
//! graph expects, and averaging/optimizer code can treat the whole model as
//! one dense vector.

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParamLayout {
    pub entries: Vec<ParamEntry>,
    pub total: usize,
}

impl ParamLayout {
    pub fn from_entries(entries: Vec<ParamEntry>) -> Result<ParamLayout> {
        let mut expect = 0usize;
        for e in &entries {
            if e.offset != expect {
                bail!("layout hole: {} at offset {} (expected {})", e.name, e.offset, expect);
            }
            let numel: usize = e.shape.iter().product::<usize>().max(1);
            if numel != e.size {
                bail!("layout size mismatch for {}: shape {:?} vs size {}", e.name, e.shape, e.size);
            }
            expect += e.size;
        }
        Ok(ParamLayout { entries, total: expect })
    }

    pub fn from_json(v: &Json) -> Result<ParamLayout> {
        let mut entries = Vec::new();
        for e in v.as_arr()? {
            entries.push(ParamEntry {
                name: e.req("name")?.as_str()?.to_string(),
                shape: e.req("shape")?.usize_arr()?,
                offset: e.req("offset")?.as_usize()?,
                size: e.req("size")?.as_usize()?,
            });
        }
        ParamLayout::from_entries(entries)
    }

    /// Tensor `i`'s slice of a flat buffer.
    pub fn slice<'a>(&self, i: usize, flat: &'a [f32]) -> &'a [f32] {
        let e = &self.entries[i];
        &flat[e.offset..e.offset + e.size]
    }

    pub fn slice_mut<'a>(&self, i: usize, flat: &'a mut [f32]) -> &'a mut [f32] {
        let e = &self.entries[i];
        &mut flat[e.offset..e.offset + e.size]
    }

    pub fn n_tensors(&self) -> usize {
        self.entries.len()
    }
}

/// One learner's parameters as a dense vector.
pub type FlatParams = Vec<f32>;

/// A flat per-learner arena: `rows` dense vectors of `stride` f32s in ONE
/// contiguous allocation (`row j` lives at `data[j*stride .. (j+1)*stride]`).
///
/// This is the data-oriented replacement for `Vec<FlatParams>` learner
/// state: a contiguous range of rows is a contiguous `&mut [f32]`, so the
/// executor pool can chunk replicas/grads/optimizer state at row
/// granularity (`WorkerPool::run_chunks_mut` with `chunk_len = stride`),
/// and first-touch page placement covers *all* learner state, not just
/// collective shards.  Row views expose exactly the same `&[f32]` /
/// `&mut [f32]` slices the `Vec<Vec<f32>>` path handed out, so every
/// consumer performs the same IEEE ops in the same order — the arena is a
/// layout change, never a numerics change.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamArena {
    data: Vec<f32>,
    stride: usize,
    rows: usize,
}

impl ParamArena {
    /// `rows` zeroed rows of `stride` elements.
    pub fn zeroed(rows: usize, stride: usize) -> ParamArena {
        ParamArena { data: vec![0.0; rows * stride], stride, rows }
    }

    /// `rows` copies of `init` (the replicated-initialization pattern).
    pub fn replicated(init: &[f32], rows: usize) -> ParamArena {
        let stride = init.len();
        let mut data = Vec::with_capacity(rows * stride);
        for _ in 0..rows {
            data.extend_from_slice(init);
        }
        ParamArena { data, stride, rows }
    }

    /// Pack per-learner vectors into an arena (all rows must share a
    /// length).  Test/bench helper for converting legacy `Vec<Vec<f32>>`.
    pub fn from_rows(rows: &[Vec<f32>]) -> ParamArena {
        let stride = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(rows.len() * stride);
        for r in rows {
            assert_eq!(r.len(), stride, "arena rows must share a length");
            data.extend_from_slice(r);
        }
        ParamArena { data, stride, rows: rows.len() }
    }

    /// Unpack back into per-learner vectors (test/bench helper).
    pub fn to_vecs(&self) -> Vec<Vec<f32>> {
        (0..self.rows).map(|j| self.row(j).to_vec()).collect()
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    pub fn row(&self, j: usize) -> &[f32] {
        &self.data[j * self.stride..(j + 1) * self.stride]
    }

    pub fn row_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.data[j * self.stride..(j + 1) * self.stride]
    }

    /// The whole arena as one flat slice (row-granular pool chunking and
    /// first-touch placement dispatch over this).
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Shared view over all rows.
    pub fn view(&self) -> Rows<'_> {
        Rows { data: &self.data, stride: self.stride, rows: self.rows }
    }

    /// Mutable view over all rows.
    pub fn view_mut(&mut self) -> RowsMut<'_> {
        RowsMut { data: &mut self.data, stride: self.stride, rows: self.rows }
    }
}

/// A shared (read-only) view of arena rows: `Copy`, so parallel readers —
/// pool tasks, scoped threads — can each capture the whole view and slice
/// out the rows they need.
#[derive(Clone, Copy, Debug)]
pub struct Rows<'a> {
    data: &'a [f32],
    stride: usize,
    rows: usize,
}

impl<'a> Rows<'a> {
    /// View a single standalone vector as a one-row arena (adapter for
    /// callers holding a plain `&[f32]`, e.g. ASGD snapshots).
    pub fn single(row: &'a [f32]) -> Rows<'a> {
        Rows { data: row, stride: row.len(), rows: 1 }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    pub fn row(&self, j: usize) -> &'a [f32] {
        &self.data[j * self.stride..(j + 1) * self.stride]
    }
}

/// A mutable view of arena rows.  Reborrowable (`reborrow`) so one view
/// can be threaded through per-group reduction calls, and splittable at a
/// row boundary (`split_rows_at`) so per-lane backends can own disjoint
/// row ranges by value.
#[derive(Debug)]
pub struct RowsMut<'a> {
    data: &'a mut [f32],
    stride: usize,
    rows: usize,
}

impl<'a> RowsMut<'a> {
    /// View a single standalone vector as a one-row mutable arena.
    pub fn single(row: &'a mut [f32]) -> RowsMut<'a> {
        let stride = row.len();
        RowsMut { data: row, stride, rows: 1 }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    pub fn row(&self, j: usize) -> &[f32] {
        &self.data[j * self.stride..(j + 1) * self.stride]
    }

    pub fn row_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.data[j * self.stride..(j + 1) * self.stride]
    }

    /// A shorter-lived mutable view of the same rows (lets `&mut self`
    /// callers hand the view to a callee without giving it up).
    pub fn reborrow(&mut self) -> RowsMut<'_> {
        RowsMut { data: self.data, stride: self.stride, rows: self.rows }
    }

    /// Shared view of the same rows.
    pub fn as_shared(&self) -> Rows<'_> {
        Rows { data: self.data, stride: self.stride, rows: self.rows }
    }

    /// The contiguous flat slice covering rows `r` (group broadcasts and
    /// row-granular pool chunking go through this).
    pub fn range_mut(&mut self, r: std::ops::Range<usize>) -> &mut [f32] {
        &mut self.data[r.start * self.stride..r.end * self.stride]
    }

    /// Split into two disjoint views at row `mid` (by value — each half
    /// keeps the full lifetime, for per-lane ownership).
    pub fn split_rows_at(self, mid: usize) -> (RowsMut<'a>, RowsMut<'a>) {
        let (lo, hi) = self.data.split_at_mut(mid * self.stride);
        (
            RowsMut { data: lo, stride: self.stride, rows: mid },
            RowsMut { data: hi, stride: self.stride, rows: self.rows - mid },
        )
    }
}

/// Load an `<name>.init.bin` blob (little-endian f32) and validate its
/// length against the layout.
pub fn load_init_blob(path: &std::path::Path, layout: &ParamLayout) -> Result<FlatParams> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != layout.total * 4 {
        bail!(
            "init blob {} has {} bytes, layout expects {}",
            path.display(),
            bytes.len(),
            layout.total * 4
        );
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout2() -> ParamLayout {
        ParamLayout::from_entries(vec![
            ParamEntry { name: "w".into(), shape: vec![2, 3], offset: 0, size: 6 },
            ParamEntry { name: "b".into(), shape: vec![3], offset: 6, size: 3 },
        ])
        .unwrap()
    }

    #[test]
    fn slicing() {
        let l = layout2();
        assert_eq!(l.total, 9);
        let flat: Vec<f32> = (0..9).map(|i| i as f32).collect();
        assert_eq!(l.slice(0, &flat), &[0., 1., 2., 3., 4., 5.]);
        assert_eq!(l.slice(1, &flat), &[6., 7., 8.]);
    }

    #[test]
    fn rejects_holes_and_mismatches() {
        assert!(ParamLayout::from_entries(vec![ParamEntry {
            name: "w".into(),
            shape: vec![2],
            offset: 4,
            size: 2
        }])
        .is_err());
        assert!(ParamLayout::from_entries(vec![ParamEntry {
            name: "w".into(),
            shape: vec![2, 2],
            offset: 0,
            size: 3
        }])
        .is_err());
    }

    #[test]
    fn from_json() {
        let j = Json::parse(
            r#"[{"name":"w","shape":[2,3],"offset":0,"size":6},
                {"name":"b","shape":[3],"offset":6,"size":3}]"#,
        )
        .unwrap();
        assert_eq!(ParamLayout::from_json(&j).unwrap(), layout2());
    }

    #[test]
    fn arena_roundtrip_and_views() {
        let rows: Vec<Vec<f32>> =
            (0..4).map(|j| (0..3).map(|i| (j * 3 + i) as f32).collect()).collect();
        let mut a = ParamArena::from_rows(&rows);
        assert_eq!((a.rows(), a.stride()), (4, 3));
        assert_eq!(a.to_vecs(), rows);
        assert_eq!(a.row(2), &[6.0, 7.0, 8.0]);
        // Views hand out the same slices the Vec<Vec<f32>> path did.
        let v = a.view();
        for j in 0..4 {
            assert_eq!(v.row(j), rows[j].as_slice());
        }
        let mut m = a.view_mut();
        m.row_mut(1)[0] = 99.0;
        // range_mut covers contiguous row ranges.
        assert_eq!(m.range_mut(1..3).len(), 6);
        assert_eq!(m.range_mut(1..3)[0], 99.0);
        // split_rows_at yields disjoint halves with arena geometry.
        let (lo, hi) = m.split_rows_at(1);
        assert_eq!((lo.rows(), hi.rows()), (1, 3));
        assert_eq!(hi.row(0)[0], 99.0); // old row 1
        assert_eq!(a.row(1)[0], 99.0);

        let z = ParamArena::zeroed(2, 5);
        assert_eq!(z.as_slice(), &[0.0; 10][..]);
        let r = ParamArena::replicated(&[1.0, 2.0], 3);
        assert_eq!(r.as_slice(), &[1.0, 2.0, 1.0, 2.0, 1.0, 2.0][..]);

        // Single-row adapters wrap a standalone vector in arena geometry.
        let mut one = vec![5.0f32, 6.0];
        assert_eq!(Rows::single(&one).row(0), &[5.0, 6.0]);
        let mut w = RowsMut::single(&mut one);
        assert_eq!((w.rows(), w.stride()), (1, 2));
        w.row_mut(0)[1] = 7.0;
        assert_eq!(w.as_shared().row(0), &[5.0, 7.0]);
        assert_eq!(one, vec![5.0, 7.0]);
    }

    #[test]
    fn init_blob_roundtrip() {
        let l = layout2();
        let dir = std::env::temp_dir().join("hier_avg_test_blob");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.init.bin");
        let vals: Vec<f32> = (0..9).map(|i| i as f32 * 0.5).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(load_init_blob(&p, &l).unwrap(), vals);
        std::fs::write(&p, [0u8; 7]).unwrap();
        assert!(load_init_blob(&p, &l).is_err());
    }
}
