//! Flat parameter buffers and their tensor layout.
//!
//! Every learner replica holds one contiguous `Vec<f32>` with all model
//! parameters.  `ParamLayout` (mirroring `artifacts/manifest.json`) maps
//! tensor names to (shape, offset, len) so the XLA runtime can slice the
//! buffer into per-tensor literals in exactly the order the AOT-lowered
//! graph expects, and averaging/optimizer code can treat the whole model as
//! one dense vector.

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParamLayout {
    pub entries: Vec<ParamEntry>,
    pub total: usize,
}

impl ParamLayout {
    pub fn from_entries(entries: Vec<ParamEntry>) -> Result<ParamLayout> {
        let mut expect = 0usize;
        for e in &entries {
            if e.offset != expect {
                bail!("layout hole: {} at offset {} (expected {})", e.name, e.offset, expect);
            }
            let numel: usize = e.shape.iter().product::<usize>().max(1);
            if numel != e.size {
                bail!("layout size mismatch for {}: shape {:?} vs size {}", e.name, e.shape, e.size);
            }
            expect += e.size;
        }
        Ok(ParamLayout { entries, total: expect })
    }

    pub fn from_json(v: &Json) -> Result<ParamLayout> {
        let mut entries = Vec::new();
        for e in v.as_arr()? {
            entries.push(ParamEntry {
                name: e.req("name")?.as_str()?.to_string(),
                shape: e.req("shape")?.usize_arr()?,
                offset: e.req("offset")?.as_usize()?,
                size: e.req("size")?.as_usize()?,
            });
        }
        ParamLayout::from_entries(entries)
    }

    /// Tensor `i`'s slice of a flat buffer.
    pub fn slice<'a>(&self, i: usize, flat: &'a [f32]) -> &'a [f32] {
        let e = &self.entries[i];
        &flat[e.offset..e.offset + e.size]
    }

    pub fn slice_mut<'a>(&self, i: usize, flat: &'a mut [f32]) -> &'a mut [f32] {
        let e = &self.entries[i];
        &mut flat[e.offset..e.offset + e.size]
    }

    pub fn n_tensors(&self) -> usize {
        self.entries.len()
    }
}

/// One learner's parameters as a dense vector.
pub type FlatParams = Vec<f32>;

/// Load an `<name>.init.bin` blob (little-endian f32) and validate its
/// length against the layout.
pub fn load_init_blob(path: &std::path::Path, layout: &ParamLayout) -> Result<FlatParams> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != layout.total * 4 {
        bail!(
            "init blob {} has {} bytes, layout expects {}",
            path.display(),
            bytes.len(),
            layout.total * 4
        );
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout2() -> ParamLayout {
        ParamLayout::from_entries(vec![
            ParamEntry { name: "w".into(), shape: vec![2, 3], offset: 0, size: 6 },
            ParamEntry { name: "b".into(), shape: vec![3], offset: 6, size: 3 },
        ])
        .unwrap()
    }

    #[test]
    fn slicing() {
        let l = layout2();
        assert_eq!(l.total, 9);
        let flat: Vec<f32> = (0..9).map(|i| i as f32).collect();
        assert_eq!(l.slice(0, &flat), &[0., 1., 2., 3., 4., 5.]);
        assert_eq!(l.slice(1, &flat), &[6., 7., 8.]);
    }

    #[test]
    fn rejects_holes_and_mismatches() {
        assert!(ParamLayout::from_entries(vec![ParamEntry {
            name: "w".into(),
            shape: vec![2],
            offset: 4,
            size: 2
        }])
        .is_err());
        assert!(ParamLayout::from_entries(vec![ParamEntry {
            name: "w".into(),
            shape: vec![2, 2],
            offset: 0,
            size: 3
        }])
        .is_err());
    }

    #[test]
    fn from_json() {
        let j = Json::parse(
            r#"[{"name":"w","shape":[2,3],"offset":0,"size":6},
                {"name":"b","shape":[3],"offset":6,"size":3}]"#,
        )
        .unwrap();
        assert_eq!(ParamLayout::from_json(&j).unwrap(), layout2());
    }

    #[test]
    fn init_blob_roundtrip() {
        let l = layout2();
        let dir = std::env::temp_dir().join("hier_avg_test_blob");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.init.bin");
        let vals: Vec<f32> = (0..9).map(|i| i as f32 * 0.5).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(load_init_blob(&p, &l).unwrap(), vals);
        std::fs::write(&p, [0u8; 7]).unwrap();
        assert!(load_init_blob(&p, &l).is_err());
    }
}
